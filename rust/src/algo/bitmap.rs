//! Bitmap hub-row intersections + tail-side segmentation — the per-row
//! **hybrid representation** (ROADMAP item 5).
//!
//! The eager merge's worst case is a heavy *partner* row: every slot
//! `(i, κ)` with a hub `κ` re-walks row `κ`'s live entries, and under
//! the segment split ([`crate::algo::support::segment_tasks`]) such a
//! slot still fans out into `ceil(live(κ)/len)` tasks whose collective
//! overhead scales with the partner, not with the slot's own tail. The
//! K-Clique-on-GPUs line (arXiv 2104.13209) shows the fix: encode the
//! heavy row as **dense words over a row-local universe** and turn the
//! merge-walk into word-indexed AND + popcount probes; GraphBLAST
//! (arXiv 1908.01407) frames the same move as a masked-intersection
//! kernel choice made per operand. Here that choice is per row:
//!
//! * [`BitmapIndex::build`] bitmap-encodes every row whose live length
//!   reaches the threshold (the plan layer passes the same
//!   `auto_segment_len`-derived value that sizes segments, so the
//!   representation choice rides the measured cost distribution), with
//!   a density guard — a row is only encoded if its word count does not
//!   exceed its live count, bounding bitmap memory by 8 B per live
//!   entry (parity with the column data itself).
//! * Slots whose partner row is encoded run **tail-side segmentation**:
//!   the slot's own tail splits into ≤`len`-entry [`BitmapTask`] chunks,
//!   each probing its chunk against the partner bitmap. Task cost is
//!   exactly the chunk length — one uniform-cost probe per tail entry —
//!   which bounds the previously unbounded `tail_end - p` factor and is
//!   the uniform per-word shape the warp model rewards.
//! * Slots whose partner stays in merge representation fall back to the
//!   partner-side [`SegTask`] split; [`hybrid_tasks`] returns both
//!   lists, executed together by [`crate::par::parallel_support`].
//!
//! A probe recovers the *partner slot* `r` (not just membership) from a
//! per-word exclusive rank prefix, so the kernels bump all three edge
//! supports exactly like the merge kernels and hybrid passes stay
//! byte-identical to [`compute_supports_seq`](crate::algo::support::compute_supports_seq).

use crate::algo::support::{eager_update_segment_seq, SegTask};
use crate::graph::zeroterm::ZCsr;
use crate::graph::Vid;
use std::sync::atomic::{AtomicU32, Ordering};

/// Intersection representation chosen for one row (as *partner* operand).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowRepr {
    /// Sorted-merge / segment-probe representation (the default).
    Merge,
    /// Dense word-block bitmap over the row-local value universe.
    Bitmap,
}

/// Bitmap encoding of one row's live entries: dense `u64` word blocks
/// over the row-local universe `[base, base + 64·words)` plus a per-word
/// exclusive rank prefix that maps a set bit back to its flat slot
/// index (`r0 + rank`), preserving the eager update's `S[r]` bump.
#[derive(Clone, Debug)]
pub struct RowBitmap {
    /// Smallest live value of the row (universe origin).
    base: Vid,
    /// Flat slot index of the row's first live entry.
    r0: u32,
    /// Dense membership words; bit `k` of word `w` is value `base + 64w + k`.
    words: Vec<u64>,
    /// Exclusive prefix popcount per word (rank of the word's first bit).
    rank: Vec<u32>,
}

impl RowBitmap {
    /// Encode the live entries of `row`; `None` if the row is empty.
    fn encode(z: &ZCsr, row: usize) -> Option<RowBitmap> {
        let live = z.row_live(row);
        let (&first, &last) = (live.first()?, live.last()?);
        let (r0, _) = z.row_span(row);
        let nwords = ((last - first) as usize >> 6) + 1;
        let mut words = vec![0u64; nwords];
        for &c in live {
            let off = (c - first) as usize;
            words[off >> 6] |= 1u64 << (off & 63);
        }
        let mut rank = Vec::with_capacity(nwords);
        let mut acc = 0u32;
        for &w in &words {
            rank.push(acc);
            acc += w.count_ones();
        }
        Some(RowBitmap { base: first, r0: r0 as u32, words, rank })
    }

    /// Membership + rank probe: the flat slot index of value `w` in the
    /// encoded row, or `None` if absent. One word load, one AND, one
    /// popcount — uniform cost per probe.
    #[inline]
    pub fn probe(&self, w: Vid) -> Option<u32> {
        let off = w.checked_sub(self.base)? as usize;
        let word = *self.words.get(off >> 6)?;
        let bit = 1u64 << (off & 63);
        if word & bit == 0 {
            return None;
        }
        let below = (word & (bit - 1)).count_ones();
        Some(self.r0 + self.rank[off >> 6] + below)
    }

    /// Number of `u64` words the encoding holds.
    pub fn word_count(&self) -> usize {
        self.words.len()
    }
}

/// Pooled per-row bitmap encodings for one support pass (rebuilt after
/// each prune, like the task lists — encodings index the *current*
/// compacted live entries).
#[derive(Clone, Debug)]
pub struct BitmapIndex {
    rows: Vec<Option<RowBitmap>>,
    encoded_rows: usize,
    total_words: usize,
}

impl BitmapIndex {
    /// Encode every row whose live length is ≥ `threshold` and whose
    /// encoding passes the density guard (`words ≤ live`, i.e. at most
    /// 8 B of bitmap per live entry). Returns the index plus the
    /// per-row [`RowRepr`] the selection settled on.
    pub fn build(z: &ZCsr, threshold: u32) -> (BitmapIndex, Vec<RowRepr>) {
        let threshold = threshold.max(1) as usize;
        let n = z.n();
        let mut rows = Vec::with_capacity(n);
        let mut reprs = vec![RowRepr::Merge; n];
        let (mut encoded_rows, mut total_words) = (0usize, 0usize);
        for (i, repr) in reprs.iter_mut().enumerate() {
            let live = z.row_live(i).len();
            let mut slot = None;
            if live >= threshold {
                if let Some(bm) = RowBitmap::encode(z, i) {
                    if bm.word_count() <= live {
                        total_words += bm.word_count();
                        encoded_rows += 1;
                        *repr = RowRepr::Bitmap;
                        slot = Some(bm);
                    }
                }
            }
            rows.push(slot);
        }
        (BitmapIndex { rows, encoded_rows, total_words }, reprs)
    }

    /// The encoding of row `i`, if it was selected for bitmap form.
    #[inline]
    pub fn row(&self, i: usize) -> Option<&RowBitmap> {
        self.rows.get(i).and_then(|r| r.as_ref())
    }

    /// Rows that carry a bitmap encoding.
    pub fn encoded_rows(&self) -> usize {
        self.encoded_rows
    }

    /// Total `u64` words across all encodings (memory telemetry).
    pub fn total_words(&self) -> usize {
        self.total_words
    }
}

/// One tail-side task of the hybrid pass: probe the tail chunk
/// `col[q_lo..q_hi]` of slot `p`'s row against the bitmap of partner
/// row `κ = col[p]`. Chunks of one slot partition its live tail, so the
/// union of chunk matches is exactly the fine task's intersection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitmapTask {
    /// Flat slot index of the fine task this chunk belongs to.
    pub p: u32,
    /// Start (inclusive) of the tail chunk, as a flat slot index (> `p`).
    pub q_lo: u32,
    /// End (exclusive) of the tail chunk.
    pub q_hi: u32,
}

impl BitmapTask {
    /// Static cost estimate in probe steps: exactly the chunk length.
    /// Unlike [`SegTask::estimated_steps`] this is not just an upper
    /// bound — the kernels execute one uniform probe per chunk entry
    /// and return *exactly* this count (the shape the warp model
    /// rewards, and what the step-invariant property tests pin).
    pub fn estimated_steps(&self) -> u64 {
        (self.q_hi - self.q_lo) as u64
    }
}

/// The mixed task list of one hybrid support pass: partner-side merge
/// segments for merge-represented partners, tail-side probe chunks for
/// bitmap-represented ones, plus the bitmap pool they probe against.
#[derive(Clone, Debug)]
pub struct HybridTasks {
    /// Per-row representation the selection pass settled on.
    pub reprs: Vec<RowRepr>,
    /// Bitmap encodings of the selected rows.
    pub index: BitmapIndex,
    /// Merge-side tasks (partner-row segments).
    pub merge: Vec<SegTask>,
    /// Bitmap-side tasks (tail chunks).
    pub probe: Vec<BitmapTask>,
}

impl HybridTasks {
    /// Total task count across both representations.
    pub fn len(&self) -> usize {
        self.merge.len() + self.probe.len()
    }

    /// Whether the pass has no work at all.
    pub fn is_empty(&self) -> bool {
        self.merge.is_empty() && self.probe.is_empty()
    }

    /// Estimated per-task step costs in combined task order (merge
    /// tasks first, then probe tasks) — the cost vector the work-aware
    /// and stealing schedules bin on.
    pub fn estimated_steps(&self) -> Vec<u64> {
        self.merge
            .iter()
            .map(SegTask::estimated_steps)
            .chain(self.probe.iter().map(BitmapTask::estimated_steps))
            .collect()
    }

    /// Frontier-driven invalidation (ROADMAP item 5 follow-up): bring
    /// this task list up to date with the current working form by
    /// re-running representation selection for the `changed` rows only
    /// — drop their stale encodings, re-encode the ones that still
    /// clear the threshold + density guard — then re-enumerate the
    /// task lists. Equivalent to a fresh [`hybrid_tasks`] build:
    /// prune/compaction is row-local, so a row not in `changed` has
    /// the same live entries, hence the same encoding and the same
    /// representation choice it had when last (re)built. The saving is
    /// that per-pass index maintenance is `O(changed rows)` instead of
    /// `O(n)` re-encoding.
    ///
    /// `changed` must contain every row whose live entries changed
    /// since this task list last described `z` (the convergence
    /// drivers accumulate the frontier's rows); duplicates and
    /// since-unchanged rows are harmless.
    pub fn refresh(&mut self, z: &ZCsr, len: u32, changed: &[u32]) {
        let len = len.max(1) as usize;
        for &row in changed {
            let i = row as usize;
            if let Some(old) = self.index.rows[i].take() {
                self.index.encoded_rows -= 1;
                self.index.total_words -= old.word_count();
            }
            self.reprs[i] = RowRepr::Merge;
            let live = z.row_live(i).len();
            if live >= len {
                if let Some(bm) = RowBitmap::encode(z, i) {
                    if bm.word_count() <= live {
                        self.index.total_words += bm.word_count();
                        self.index.encoded_rows += 1;
                        self.reprs[i] = RowRepr::Bitmap;
                        self.index.rows[i] = Some(bm);
                    }
                }
            }
        }
        let (merge, probe) = enumerate_tasks(z, len, &self.index);
        self.merge = merge;
        self.probe = probe;
    }
}

/// Enumerate the hybrid task list: select row representations at
/// threshold `len`, then for every live slot `p` with a non-empty tail
/// and non-empty partner row emit either ≤`len`-entry tail chunks
/// ([`BitmapTask`], partner encoded) or ≤`len`-entry partner segments
/// ([`SegTask`], partner in merge form). Trivially empty slots produce
/// no tasks, exactly like [`crate::algo::support::segment_tasks`].
pub fn hybrid_tasks(z: &ZCsr, len: u32) -> HybridTasks {
    let len = len.max(1) as usize;
    let (index, reprs) = BitmapIndex::build(z, len as u32);
    let (merge, probe) = enumerate_tasks(z, len, &index);
    HybridTasks { reprs, index, merge, probe }
}

/// The task-enumeration half of [`hybrid_tasks`], against an existing
/// representation selection: shared by the fresh build and by
/// [`HybridTasks::refresh`], so both produce identical task lists for
/// the same working form + index state. `len` is already clamped ≥ 1.
fn enumerate_tasks(z: &ZCsr, len: usize, index: &BitmapIndex) -> (Vec<SegTask>, Vec<BitmapTask>) {
    let col = z.col();
    let n = z.n();
    let live: Vec<u32> = (0..n).map(|i| z.row_live(i).len() as u32).collect();
    let mut merge = Vec::new();
    let mut probe = Vec::new();
    for i in 0..n {
        let (start, _) = z.row_span(i);
        let li = live[i] as usize;
        let tail_end = (start + li) as u32;
        for off in 0..li {
            let p = start + off;
            let tail_len = li - off - 1;
            if tail_len == 0 {
                continue; // last live slot: empty tail, no work
            }
            let kappa = col[p] as usize;
            let lk = live[kappa] as usize;
            if lk == 0 {
                continue; // empty partner row, no work
            }
            if index.row(kappa).is_some() {
                // tail-side segmentation against the partner bitmap
                let mut q = p + 1;
                while q < tail_end as usize {
                    let q_hi = (q + len).min(tail_end as usize);
                    probe.push(BitmapTask {
                        p: p as u32,
                        q_lo: q as u32,
                        q_hi: q_hi as u32,
                    });
                    q = q_hi;
                }
            } else {
                // partner-side segmentation, as in `segment_tasks`
                let (r0, _) = z.row_span(kappa);
                let mut lo = 0usize;
                while lo < lk {
                    let hi = (lo + len).min(lk);
                    merge.push(SegTask {
                        p: p as u32,
                        tail_end,
                        lo: (r0 + lo) as u32,
                        hi: (r0 + hi) as u32,
                    });
                    lo = hi;
                }
            }
        }
    }
    (merge, probe)
}

/// Eager update for one [`BitmapTask`], sequential support array:
/// probe every chunk entry against the partner bitmap, bumping all
/// three edge supports on a hit. Returns exactly
/// [`BitmapTask::estimated_steps`] — one uniform step per probe.
#[inline]
pub fn eager_update_bitmap_seq(col: &[Vid], s: &mut [u32], bm: &RowBitmap, t: &BitmapTask) -> u64 {
    let p = t.p as usize;
    for q in t.q_lo as usize..t.q_hi as usize {
        if let Some(r) = bm.probe(col[q]) {
            s[p] += 1;
            s[q] += 1;
            s[r as usize] += 1;
        }
    }
    t.estimated_steps()
}

/// Atomic variant of [`eager_update_bitmap_seq`] for the pool: chunks
/// of the same fine task race on `s[p]` (and on shared partner-row
/// slots), so every bump is a relaxed fetch-add.
#[inline]
pub fn eager_update_bitmap_atomic(
    col: &[Vid],
    s: &[AtomicU32],
    bm: &RowBitmap,
    t: &BitmapTask,
) -> u64 {
    let p = t.p as usize;
    for q in t.q_lo as usize..t.q_hi as usize {
        if let Some(r) = bm.probe(col[q]) {
            s[p].fetch_add(1, Ordering::Relaxed);
            s[q].fetch_add(1, Ordering::Relaxed);
            s[r as usize].fetch_add(1, Ordering::Relaxed);
        }
    }
    t.estimated_steps()
}

/// Sequential hybrid `computeSupports`: clears `s`, enumerates
/// [`hybrid_tasks`] and applies every merge segment and probe chunk.
/// Returns total executed steps. The result is identical to
/// [`compute_supports_seq`](crate::algo::support::compute_supports_seq)
/// — verified by the hybrid property tests.
pub fn compute_supports_hybrid_seq(z: &ZCsr, len: u32, s: &mut Vec<u32>) -> u64 {
    s.clear();
    s.resize(z.slots(), 0);
    let ht = hybrid_tasks(z, len);
    let col = z.col();
    let mut steps = 0u64;
    for t in &ht.merge {
        steps += eager_update_segment_seq(col, s, t);
    }
    for t in &ht.probe {
        let kappa = col[t.p as usize] as usize;
        let bm = ht.index.row(kappa).expect("probe task against unencoded row");
        steps += eager_update_bitmap_seq(col, s, bm, t);
    }
    steps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::support::compute_supports_seq;
    use crate::graph::builder::from_sorted_unique;
    use crate::testkit::graphs;

    #[test]
    fn probe_recovers_exact_slots() {
        // row 0 live: [2, 5, 70, 131] — spans three words
        let g = from_sorted_unique(132, &[(0, 2), (0, 5), (0, 70), (0, 131), (1, 2)]);
        let z = ZCsr::from_csr(&g);
        let bm = RowBitmap::encode(&z, 0).unwrap();
        let (r0, _) = z.row_span(0);
        for (off, &c) in z.row_live(0).iter().enumerate() {
            assert_eq!(bm.probe(c), Some((r0 + off) as u32), "value {c}");
        }
        for miss in [0u32, 1, 3, 69, 71, 130, 132, 4000] {
            assert_eq!(bm.probe(miss), None, "value {miss}");
        }
        assert_eq!(bm.word_count(), ((131 - 2) >> 6) + 1);
    }

    #[test]
    fn density_guard_demotes_sparse_wide_rows() {
        // two live entries spanning a huge universe: words ≫ live
        let g = from_sorted_unique(20_000, &[(0, 1), (0, 19_999), (1, 2)]);
        let z = ZCsr::from_csr(&g);
        let (index, reprs) = BitmapIndex::build(&z, 1);
        assert_eq!(reprs[0], RowRepr::Merge, "sparse wide row must stay merge");
        assert!(index.row(0).is_none());
        // row 1 ([2]) is dense over a 1-value universe: encoded
        assert_eq!(reprs[1], RowRepr::Bitmap);
        assert_eq!(index.encoded_rows(), 1);
        assert_eq!(index.total_words(), 1);
    }

    #[test]
    fn hybrid_tasks_bound_both_sides_and_cover_all_slots() {
        let g = graphs::hub_divergence_comb(20, 30, 150);
        let z = ZCsr::from_csr(&g);
        for len in [1u32, 8, 64] {
            let ht = hybrid_tasks(&z, len);
            for t in &ht.merge {
                assert!(t.hi - t.lo <= len, "{t:?}");
                assert!(t.estimated_steps() <= len as u64 + 1, "{t:?}");
            }
            for t in &ht.probe {
                assert!(t.q_lo > t.p && t.q_hi > t.q_lo, "{t:?}");
                assert!(t.q_hi - t.q_lo <= len, "{t:?}");
            }
            // chunks of one slot must partition its live tail
            let mut by_p: std::collections::HashMap<u32, Vec<(u32, u32)>> =
                std::collections::HashMap::new();
            for t in &ht.probe {
                by_p.entry(t.p).or_default().push((t.q_lo, t.q_hi));
            }
            for (p, mut chunks) in by_p {
                chunks.sort_unstable();
                let i = z.row_of(p as usize);
                let (start, _) = z.row_span(i);
                let tail_end = start + z.row_live(i).len();
                assert_eq!(chunks.first().unwrap().0, p + 1, "p={p}");
                assert_eq!(chunks.last().unwrap().1 as usize, tail_end, "p={p}");
                for w in chunks.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "p={p}: chunks must be contiguous");
                }
            }
        }
    }

    #[test]
    fn hub_partner_rows_go_bitmap_on_the_comb() {
        // the comb's hub row (live = span) is the heavy *partner* row;
        // at a threshold below span it must be bitmap-encoded and all
        // heavy-slot work must move to the probe side
        let g = graphs::hub_divergence_comb(20, 30, 150);
        let z = ZCsr::from_csr(&g);
        let ht = hybrid_tasks(&z, 64);
        let hub = 20 + 30; // hub vertex id
        assert_eq!(ht.reprs[hub], RowRepr::Bitmap);
        assert!(ht.index.row(hub).is_some());
        assert!(!ht.probe.is_empty());
        // no merge segment may target the encoded hub row
        for t in &ht.merge {
            let kappa = z.col()[t.p as usize] as usize;
            assert_eq!(ht.reprs[kappa], RowRepr::Merge, "{t:?}");
        }
    }

    #[test]
    fn hybrid_supports_match_plain_on_fixtures() {
        let rmat = crate::gen::rmat::rmat(
            300,
            2500,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(17),
        );
        for g in [
            &graphs::diamond(),
            &graphs::clique(6),
            &graphs::star_with_fringe(40),
            &graphs::hub_divergence_comb(10, 20, 80),
            &graphs::peel_chain(8),
            &rmat,
        ] {
            let z = ZCsr::from_csr(g);
            let mut want = Vec::new();
            compute_supports_seq(&z, &mut want);
            for len in [1u32, 2, 3, 64] {
                let mut got = Vec::new();
                compute_supports_hybrid_seq(&z, len, &mut got);
                assert_eq!(got, want, "len={len}");
            }
        }
    }

    #[test]
    fn bitmap_kernel_steps_are_exact() {
        let g = graphs::star_with_fringe(100);
        let z = ZCsr::from_csr(&g);
        let ht = hybrid_tasks(&z, 16);
        let col = z.col();
        let mut s = vec![0u32; z.slots()];
        for t in &ht.probe {
            let kappa = col[t.p as usize] as usize;
            let bm = ht.index.row(kappa).unwrap();
            assert_eq!(eager_update_bitmap_seq(col, &mut s, bm, t), t.estimated_steps());
        }
    }

    #[test]
    fn empty_and_triangle_free_graphs() {
        let z = ZCsr::from_csr(&crate::graph::Csr::empty(0));
        let mut s = Vec::new();
        assert_eq!(compute_supports_hybrid_seq(&z, 8, &mut s), 0);
        assert!(s.is_empty());
        let z = ZCsr::from_csr(&graphs::path(12));
        let mut s = Vec::new();
        compute_supports_hybrid_seq(&z, 8, &mut s);
        assert!(s.iter().all(|&x| x == 0));
    }
}
