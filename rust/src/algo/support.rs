//! `computeSupports` — Step 1 of the Eager K-truss algorithm.
//!
//! Both parallel granularities run the *identical* eager update kernel
//! (the sorted-merge neighborhood intersection of paper Listing 1); they
//! differ only in what a task is:
//!
//! * **coarse** (Algorithm 2): one task per row `i` — the task walks all
//!   live entries `j` of `a₁₂ᵀ` and applies the update rules for each.
//! * **fine** (Algorithm 3, the contribution): one task per nonzero slot
//!   `(i, j)` — the task applies the update rules for that single entry.
//!
//! For a live slot `p` holding `κ = col[p]` in row `i`, the eager update
//! merges the tail of row `i` after `p` with row `κ`. Every match `w`
//! identifies the triangle `(i, κ, w)` with `i < κ < w`, and all three
//! edge supports are bumped: `S[p]` (edge `i–κ`, the paper's `s₁₂(j)`
//! dot-product term), `S[q]` (edge `i–w`, the `s₁₂(j+1:)` term) and
//! `S[r]` (edge `κ–w`, the `S₂₂` row term). Zero terminators end both
//! walks, so no bounds are carried (§III-D).

use crate::graph::zeroterm::ZCsr;
use crate::graph::Vid;
use std::sync::atomic::{AtomicU32, Ordering};

/// How tasks are enumerated (granularity of parallelism).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// One task per row (source vertex) — the original Eager K-truss.
    Coarse,
    /// One task per nonzero — the paper's fine-grained formulation.
    Fine,
}

impl std::fmt::Display for Mode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Mode::Coarse => write!(f, "coarse"),
            Mode::Fine => write!(f, "fine"),
        }
    }
}

/// Eager update for the single live slot `p` (row tail starts at `p+1`,
/// row `κ` starts at `r0`). Sequential support array. Returns the number
/// of merge steps executed (the task's work, consumed by the cost model).
///
/// Hot path (§Perf): bounds checks are elided — safe because every row
/// of the zero-terminated CSR ends with a `0` slot (construction +
/// prune-compaction invariant, checked by `validate::check_zcsr`), so
/// the `cq/cr != 0` guards stop each walk at or before its row's
/// terminator. The less/greater advances are compiled branch-free; only
/// the (rare) match branch remains.
#[inline]
pub fn eager_update_seq(col: &[Vid], s: &mut [u32], p: usize, r0: usize) -> u64 {
    let mut q = p + 1;
    let mut r = r0;
    let mut steps: u64 = 0;
    debug_assert!(q < col.len() && r < col.len());
    // SAFETY: q and r only advance while the current value is nonzero;
    // every row ends with a zero terminator, so q/r never cross their
    // row's final slot (which is in-bounds by construction).
    //
    // §Perf note: a branch-free lagging-side advance was tried and
    // REVERTED (+14.7% — the sorted-merge branches predict well and the
    // branchless form lengthens the dependent chain; see EXPERIMENTS.md
    // §Perf iteration 1). Only bounds-check elision is kept.
    unsafe {
        let mut cq = *col.get_unchecked(q);
        let mut cr = *col.get_unchecked(r);
        while cq != 0 && cr != 0 {
            steps += 1;
            match cq.cmp(&cr) {
                std::cmp::Ordering::Less => {
                    q += 1;
                    cq = *col.get_unchecked(q);
                }
                std::cmp::Ordering::Greater => {
                    r += 1;
                    cr = *col.get_unchecked(r);
                }
                std::cmp::Ordering::Equal => {
                    // triangle (i, κ, w): bump all three edges eagerly
                    *s.get_unchecked_mut(p) += 1;
                    *s.get_unchecked_mut(q) += 1;
                    *s.get_unchecked_mut(r) += 1;
                    q += 1;
                    r += 1;
                    cq = *col.get_unchecked(q);
                    cr = *col.get_unchecked(r);
                }
            }
        }
    }
    steps
}

/// The original, bounds-checked match-based kernel, kept (a) as the
/// reference the optimized kernel is verified against and (b) as the
/// "before" side of the §Perf comparison in `micro_hotpath`.
#[inline]
pub fn eager_update_seq_checked(col: &[Vid], s: &mut [u32], p: usize, r0: usize) -> u64 {
    let mut q = p + 1;
    let mut r = r0;
    let mut steps: u64 = 0;
    let mut cq = col[q];
    let mut cr = col[r];
    while cq != 0 && cr != 0 {
        steps += 1;
        match cq.cmp(&cr) {
            std::cmp::Ordering::Less => {
                q += 1;
                cq = col[q];
            }
            std::cmp::Ordering::Greater => {
                r += 1;
                cr = col[r];
            }
            std::cmp::Ordering::Equal => {
                s[p] += 1;
                s[q] += 1;
                s[r] += 1;
                q += 1;
                r += 1;
                cq = col[q];
                cr = col[r];
            }
        }
    }
    steps
}

/// Full sequential support pass over the checked kernel (perf baseline).
pub fn compute_supports_seq_checked(z: &ZCsr, s: &mut Vec<u32>) {
    s.clear();
    s.resize(z.slots(), 0);
    let col = z.col();
    for i in 0..z.n() {
        let (start, end) = z.row_span(i);
        for p in start..end {
            let kappa = col[p];
            if kappa == 0 {
                break;
            }
            let (r0, _) = z.row_span(kappa as usize);
            eager_update_seq_checked(col, s, p, r0);
        }
    }
}

/// Atomic variant of [`eager_update_seq`] used by the real thread pool:
/// concurrent tasks may touch the same support slots (`S₂₂` rows are
/// shared across tasks), exactly why the paper marks `S` Atomic.
#[inline]
pub fn eager_update_atomic(col: &[Vid], s: &[AtomicU32], p: usize, r0: usize) -> u64 {
    let mut q = p + 1;
    let mut r = r0;
    let mut steps: u64 = 0;
    debug_assert!(q < col.len() && r < col.len());
    // SAFETY: identical terminator argument to `eager_update_seq`.
    unsafe {
        let mut cq = *col.get_unchecked(q);
        let mut cr = *col.get_unchecked(r);
        while cq != 0 && cr != 0 {
            steps += 1;
            match cq.cmp(&cr) {
                std::cmp::Ordering::Less => {
                    q += 1;
                    cq = *col.get_unchecked(q);
                }
                std::cmp::Ordering::Greater => {
                    r += 1;
                    cr = *col.get_unchecked(r);
                }
                std::cmp::Ordering::Equal => {
                    s.get_unchecked(p).fetch_add(1, Ordering::Relaxed);
                    s.get_unchecked(q).fetch_add(1, Ordering::Relaxed);
                    s.get_unchecked(r).fetch_add(1, Ordering::Relaxed);
                    q += 1;
                    r += 1;
                    cq = *col.get_unchecked(q);
                    cr = *col.get_unchecked(r);
                }
            }
        }
    }
    steps
}

/// Run the full coarse task for row `i` sequentially: apply the eager
/// update for every live slot of the row. Returns total merge steps.
///
/// §Perf note: software-prefetching the next task's partner row was
/// tried and REVERTED (±0% on the 150k-edge workload — partner rows are
/// largely cache-resident; see EXPERIMENTS.md §Perf iteration 3).
#[inline]
pub fn row_task_seq(z: &ZCsr, s: &mut [u32], i: usize) -> u64 {
    let col = z.col();
    let (start, end) = z.row_span(i);
    let mut steps = 0u64;
    for p in start..end {
        let kappa = col[p];
        if kappa == 0 {
            break; // terminator — rest of row is dead
        }
        let (r0, _) = z.row_span(kappa as usize);
        steps += eager_update_seq(col, s, p, r0);
    }
    steps
}

/// Sequential `computeSupports`: clears `s` and applies the eager update
/// over all rows. This is the single-thread execution used both for the
/// ground-truth result and for wallclock calibration of the simulators.
pub fn compute_supports_seq(z: &ZCsr, s: &mut Vec<u32>) {
    s.clear();
    s.resize(z.slots(), 0);
    for i in 0..z.n() {
        row_task_seq(z, s, i);
    }
}

/// Support slot values give the triangle count per live edge; the total
/// triangle count of the graph is `sum(S) / 3` (each triangle bumps
/// three slots).
pub fn total_triangles(s: &[u32]) -> u64 {
    s.iter().map(|&x| x as u64).sum::<u64>() / 3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_sorted_unique;
    use crate::graph::Csr;

    fn supports_of(g: &Csr) -> (ZCsr, Vec<u32>) {
        let z = ZCsr::from_csr(g);
        let mut s = Vec::new();
        compute_supports_seq(&z, &mut s);
        (z, s)
    }

    /// Collect (u, v, support) triples for live edges.
    fn edge_supports(z: &ZCsr, s: &[u32]) -> Vec<(u32, u32, u32)> {
        let mut out = Vec::new();
        for i in 0..z.n() {
            let (start, _) = z.row_span(i);
            for (off, &c) in z.row_live(i).iter().enumerate() {
                out.push((i as u32, c, s[start + off]));
            }
        }
        out
    }

    #[test]
    fn triangle_graph() {
        let g = from_sorted_unique(3, &[(0, 1), (0, 2), (1, 2)]);
        let (z, s) = supports_of(&g);
        let es = edge_supports(&z, &s);
        assert_eq!(es, vec![(0, 1, 1), (0, 2, 1), (1, 2, 1)]);
        assert_eq!(total_triangles(&s), 1);
    }

    #[test]
    fn diamond_graph() {
        // triangles {0,1,2} and {0,2,3}; edge (0,2) is in both
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let (z, s) = supports_of(&g);
        let es = edge_supports(&z, &s);
        assert_eq!(
            es,
            vec![(0, 1, 1), (0, 2, 2), (0, 3, 1), (1, 2, 1), (2, 3, 1)]
        );
        assert_eq!(total_triangles(&s), 2);
    }

    #[test]
    fn k4_every_edge_in_two_triangles() {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        let (z, s) = supports_of(&g);
        for (u, v, sup) in edge_supports(&z, &s) {
            assert_eq!(sup, 2, "edge ({u},{v})");
        }
        assert_eq!(total_triangles(&s), 4);
    }

    #[test]
    fn triangle_free_graph_zero_support() {
        // 5-cycle: no triangles
        let g = from_sorted_unique(5, &[(0, 1), (0, 4), (1, 2), (2, 3), (3, 4)]);
        let (_, s) = supports_of(&g);
        assert!(s.iter().all(|&x| x == 0));
    }

    #[test]
    fn optimized_kernel_matches_checked_kernel() {
        let g = crate::gen::rmat::rmat(
            400,
            3000,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(321),
        );
        let z = ZCsr::from_csr(&g);
        let mut fast = Vec::new();
        compute_supports_seq(&z, &mut fast);
        let mut checked = Vec::new();
        compute_supports_seq_checked(&z, &mut checked);
        assert_eq!(fast, checked);
    }

    #[test]
    fn atomic_matches_seq() {
        let g = crate::gen::erdos_renyi::gnm(200, 1500, &mut crate::util::Rng::new(5));
        let z = ZCsr::from_csr(&g);
        let mut s_seq = Vec::new();
        compute_supports_seq(&z, &mut s_seq);

        let s_at: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
        let col = z.col();
        for i in 0..z.n() {
            let (start, end) = z.row_span(i);
            for p in start..end {
                let kappa = col[p];
                if kappa == 0 {
                    break;
                }
                let (r0, _) = z.row_span(kappa as usize);
                eager_update_atomic(col, &s_at, p, r0);
            }
        }
        let s_at_plain: Vec<u32> = s_at.iter().map(|x| x.load(Ordering::Relaxed)).collect();
        assert_eq!(s_seq, s_at_plain);
    }

    #[test]
    fn steps_equal_merge_work() {
        // rows [1,2,3,0] and [3,0]: slot of (0,1) merges tail [2,3] with
        // row1 [2? no — row 1 holds [2..]]. Just sanity: steps > 0 when
        // both sides non-empty.
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let z = ZCsr::from_csr(&g);
        let mut s = vec![0u32; z.slots()];
        let steps = row_task_seq(&z, &mut s, 0);
        // (0,1): merge [2,3] vs [2] = 1 step; (0,2): [3] vs [3] = 1 step;
        // (0,3): empty tail = 0 steps
        assert_eq!(steps, 2);
        // row 3 has no entries -> no work
        let steps3 = row_task_seq(&z, &mut s, 3);
        assert_eq!(steps3, 0);
    }
}
