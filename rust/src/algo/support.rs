//! `computeSupports` — Step 1 of the Eager K-truss algorithm.
//!
//! Both parallel granularities run the *identical* eager update kernel
//! (the sorted-merge neighborhood intersection of paper Listing 1); they
//! differ only in what a task is:
//!
//! * **coarse** (Algorithm 2): one task per row `i` — the task walks all
//!   live entries `j` of `a₁₂ᵀ` and applies the update rules for each.
//! * **fine** (Algorithm 3, the contribution): one task per nonzero slot
//!   `(i, j)` — the task applies the update rules for that single entry.
//!
//! For a live slot `p` holding `κ = col[p]` in row `i`, the eager update
//! merges the tail of row `i` after `p` with row `κ`. Every match `w`
//! identifies the triangle `(i, κ, w)` with `i < κ < w`, and all three
//! edge supports are bumped: `S[p]` (edge `i–κ`, the paper's `s₁₂(j)`
//! dot-product term), `S[q]` (edge `i–w`, the `s₁₂(j+1:)` term) and
//! `S[r]` (edge `κ–w`, the `S₂₂` row term). Zero terminators end both
//! walks, so no bounds are carried (§III-D).

use crate::graph::zeroterm::ZCsr;
use crate::graph::Vid;
use std::sync::atomic::{AtomicU32, Ordering};

/// How tasks are enumerated (granularity of parallelism).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// One task per row (source vertex) — the original Eager K-truss.
    Coarse,
    /// One task per nonzero — the paper's fine-grained formulation.
    Fine,
}

impl std::fmt::Display for Mode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Mode::Coarse => write!(f, "coarse"),
            Mode::Fine => write!(f, "fine"),
        }
    }
}

/// Default partner-row segment length for [`Granularity::Segment`]
/// (nonzeros per ultra-fine task). Matches the ≤64-step segments the
/// ultra-fine ablation models.
pub const DEFAULT_SEGMENT_LEN: u32 = 64;

/// Task granularity of a support pass: the paper's coarse/fine pair
/// ([`Mode`]) plus the ultra-fine **segment split** the paper sketches
/// as future work (§III-B): each fine task's merge is further divided
/// into fixed-length segments of its partner row, so even one enormous
/// nonzero (hub×hub edge) decomposes into many near-uniform tasks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One task per row — [`Mode::Coarse`].
    Coarse,
    /// One task per nonzero slot — [`Mode::Fine`].
    Fine,
    /// One task per ≤`len`-entry segment of a fine task's partner row
    /// (see [`segment_tasks`]).
    Segment {
        /// Maximum partner-row entries per segment task (≥ 1).
        len: u32,
    },
    /// Per-row hybrid representation ([`crate::algo::bitmap`]): the
    /// heaviest partner rows (live length ≥ `len`) are bitmap-encoded
    /// and intersected by ≤`len`-entry **tail-side** probe chunks; the
    /// rest fall back to partner-side [`SegTask`] merges. `len` is both
    /// the hub-selection threshold and the task bound, tying the
    /// representation choice to the same cost distribution that drives
    /// `auto_segment_len`.
    Hybrid {
        /// Hub-row threshold and maximum entries per task (≥ 1).
        len: u32,
    },
}

impl Granularity {
    /// The [`Mode`] this granularity corresponds to, when the pass can
    /// run through the plain coarse/fine kernels (`None` for the
    /// segment split, which has its own task enumeration).
    pub fn mode(self) -> Option<Mode> {
        match self {
            Granularity::Coarse => Some(Mode::Coarse),
            Granularity::Fine => Some(Mode::Fine),
            Granularity::Segment { .. } | Granularity::Hybrid { .. } => None,
        }
    }

    /// Short stable label for config/table keys: `C`, `F`, `S<len>`,
    /// `H<len>`.
    pub fn short(self) -> String {
        match self {
            Granularity::Coarse => "C".to_string(),
            Granularity::Fine => "F".to_string(),
            Granularity::Segment { len } => format!("S{len}"),
            Granularity::Hybrid { len } => format!("H{len}"),
        }
    }
}

impl From<Mode> for Granularity {
    fn from(m: Mode) -> Granularity {
        match m {
            Mode::Coarse => Granularity::Coarse,
            Mode::Fine => Granularity::Fine,
        }
    }
}

impl std::fmt::Display for Granularity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Granularity::Coarse => write!(f, "coarse"),
            Granularity::Fine => write!(f, "fine"),
            Granularity::Segment { len } => write!(f, "segment:{len}"),
            Granularity::Hybrid { len } => write!(f, "hybrid:{len}"),
        }
    }
}

impl std::str::FromStr for Granularity {
    type Err = String;

    /// Parse `coarse`, `fine`, `segment`, `segment:<len>`, `hybrid`,
    /// `hybrid:<len>` (the CLI `--granularity` grammar).
    fn from_str(s: &str) -> Result<Granularity, String> {
        match s {
            "coarse" => Ok(Granularity::Coarse),
            "fine" => Ok(Granularity::Fine),
            "segment" => Ok(Granularity::Segment { len: DEFAULT_SEGMENT_LEN }),
            "hybrid" => Ok(Granularity::Hybrid { len: DEFAULT_SEGMENT_LEN }),
            other => {
                let seg = other
                    .strip_prefix("segment:")
                    .and_then(|l| l.parse::<u32>().ok())
                    .filter(|&l| l > 0)
                    .map(|len| Granularity::Segment { len });
                let hyb = other
                    .strip_prefix("hybrid:")
                    .and_then(|l| l.parse::<u32>().ok())
                    .filter(|&l| l > 0)
                    .map(|len| Granularity::Hybrid { len });
                seg.or(hyb).ok_or_else(|| {
                    format!(
                        "unknown granularity {other:?} \
                         (expected coarse|fine|segment[:len]|hybrid[:len])"
                    )
                })
            }
        }
    }
}

/// Eager update for the single live slot `p` (row tail starts at `p+1`,
/// row `κ` starts at `r0`). Sequential support array. Returns the number
/// of merge steps executed (the task's work, consumed by the cost model).
///
/// Hot path (§Perf): bounds checks are elided — safe because every row
/// of the zero-terminated CSR ends with a `0` slot (construction +
/// prune-compaction invariant, checked by `validate::check_zcsr`), so
/// the `cq/cr != 0` guards stop each walk at or before its row's
/// terminator. The less/greater advances are compiled branch-free; only
/// the (rare) match branch remains.
#[inline]
pub fn eager_update_seq(col: &[Vid], s: &mut [u32], p: usize, r0: usize) -> u64 {
    let mut q = p + 1;
    let mut r = r0;
    let mut steps: u64 = 0;
    debug_assert!(q < col.len() && r < col.len());
    // SAFETY: q and r only advance while the current value is nonzero;
    // every row ends with a zero terminator, so q/r never cross their
    // row's final slot (which is in-bounds by construction).
    //
    // §Perf note: a branch-free lagging-side advance was tried and
    // REVERTED (+14.7% — the sorted-merge branches predict well and the
    // branchless form lengthens the dependent chain; see EXPERIMENTS.md
    // §Perf iteration 1). Only bounds-check elision is kept.
    unsafe {
        let mut cq = *col.get_unchecked(q);
        let mut cr = *col.get_unchecked(r);
        while cq != 0 && cr != 0 {
            steps += 1;
            match cq.cmp(&cr) {
                std::cmp::Ordering::Less => {
                    q += 1;
                    cq = *col.get_unchecked(q);
                }
                std::cmp::Ordering::Greater => {
                    r += 1;
                    cr = *col.get_unchecked(r);
                }
                std::cmp::Ordering::Equal => {
                    // triangle (i, κ, w): bump all three edges eagerly
                    *s.get_unchecked_mut(p) += 1;
                    *s.get_unchecked_mut(q) += 1;
                    *s.get_unchecked_mut(r) += 1;
                    q += 1;
                    r += 1;
                    cq = *col.get_unchecked(q);
                    cr = *col.get_unchecked(r);
                }
            }
        }
    }
    steps
}

/// The original, bounds-checked match-based kernel, kept (a) as the
/// reference the optimized kernel is verified against and (b) as the
/// "before" side of the §Perf comparison in `micro_hotpath`.
#[inline]
pub fn eager_update_seq_checked(col: &[Vid], s: &mut [u32], p: usize, r0: usize) -> u64 {
    let mut q = p + 1;
    let mut r = r0;
    let mut steps: u64 = 0;
    let mut cq = col[q];
    let mut cr = col[r];
    while cq != 0 && cr != 0 {
        steps += 1;
        match cq.cmp(&cr) {
            std::cmp::Ordering::Less => {
                q += 1;
                cq = col[q];
            }
            std::cmp::Ordering::Greater => {
                r += 1;
                cr = col[r];
            }
            std::cmp::Ordering::Equal => {
                s[p] += 1;
                s[q] += 1;
                s[r] += 1;
                q += 1;
                r += 1;
                cq = col[q];
                cr = col[r];
            }
        }
    }
    steps
}

/// Full sequential support pass over the checked kernel (perf
/// baseline). Returns total merge steps.
pub fn compute_supports_seq_checked(z: &ZCsr, s: &mut Vec<u32>) -> u64 {
    s.clear();
    s.resize(z.slots(), 0);
    let col = z.col();
    let mut steps = 0u64;
    for i in 0..z.n() {
        let (start, end) = z.row_span(i);
        for p in start..end {
            let kappa = col[p];
            if kappa == 0 {
                break;
            }
            let (r0, _) = z.row_span(kappa as usize);
            steps += eager_update_seq_checked(col, s, p, r0);
        }
    }
    steps
}

/// Atomic variant of [`eager_update_seq`] used by the real thread pool:
/// concurrent tasks may touch the same support slots (`S₂₂` rows are
/// shared across tasks), exactly why the paper marks `S` Atomic.
#[inline]
pub fn eager_update_atomic(col: &[Vid], s: &[AtomicU32], p: usize, r0: usize) -> u64 {
    let mut q = p + 1;
    let mut r = r0;
    let mut steps: u64 = 0;
    debug_assert!(q < col.len() && r < col.len());
    // SAFETY: identical terminator argument to `eager_update_seq`.
    unsafe {
        let mut cq = *col.get_unchecked(q);
        let mut cr = *col.get_unchecked(r);
        while cq != 0 && cr != 0 {
            steps += 1;
            match cq.cmp(&cr) {
                std::cmp::Ordering::Less => {
                    q += 1;
                    cq = *col.get_unchecked(q);
                }
                std::cmp::Ordering::Greater => {
                    r += 1;
                    cr = *col.get_unchecked(r);
                }
                std::cmp::Ordering::Equal => {
                    s.get_unchecked(p).fetch_add(1, Ordering::Relaxed);
                    s.get_unchecked(q).fetch_add(1, Ordering::Relaxed);
                    s.get_unchecked(r).fetch_add(1, Ordering::Relaxed);
                    q += 1;
                    r += 1;
                    cq = *col.get_unchecked(q);
                    cr = *col.get_unchecked(r);
                }
            }
        }
    }
    steps
}

/// Run the full coarse task for row `i` sequentially: apply the eager
/// update for every live slot of the row. Returns total merge steps.
///
/// §Perf note: software-prefetching the next task's partner row was
/// tried and REVERTED (±0% on the 150k-edge workload — partner rows are
/// largely cache-resident; see EXPERIMENTS.md §Perf iteration 3).
#[inline]
pub fn row_task_seq(z: &ZCsr, s: &mut [u32], i: usize) -> u64 {
    let col = z.col();
    let (start, end) = z.row_span(i);
    let mut steps = 0u64;
    for p in start..end {
        let kappa = col[p];
        if kappa == 0 {
            break; // terminator — rest of row is dead
        }
        let (r0, _) = z.row_span(kappa as usize);
        steps += eager_update_seq(col, s, p, r0);
    }
    steps
}

/// Sequential `computeSupports`: clears `s` and applies the eager update
/// over all rows. This is the single-thread execution used both for the
/// ground-truth result and for wallclock calibration of the simulators.
/// Returns the **exact** total merge steps of the pass (the work
/// measure `IterationStat.support_steps` records — no approximation).
pub fn compute_supports_seq(z: &ZCsr, s: &mut Vec<u32>) -> u64 {
    s.clear();
    s.resize(z.slots(), 0);
    let mut steps = 0u64;
    for i in 0..z.n() {
        steps += row_task_seq(z, s, i);
    }
    steps
}

/// One ultra-fine task of the segment-split support pass: the merge of
/// row `i`'s live tail after slot `p` against the partner-row segment
/// `col[lo..hi]` (a ≤`len`-entry contiguous slice of row `κ = col[p]`'s
/// live entries). The segments of one fine task partition its partner
/// row, so the union of segment matches is exactly the fine task's
/// intersection and every `(q, r)` match pair is found once.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SegTask {
    /// Flat slot index of the fine task this segment belongs to.
    pub p: u32,
    /// End (exclusive) of the live entries of `p`'s row — the merge's
    /// left side is `col[p+1..tail_end]`.
    pub tail_end: u32,
    /// Start (inclusive) of the partner-row segment, as a flat slot index.
    pub lo: u32,
    /// End (exclusive) of the partner-row segment.
    pub hi: u32,
}

impl SegTask {
    /// Live-tail length of the fine task this segment belongs to (the
    /// merge's left side, `col[p+1..tail_end]`).
    pub fn tail_len(&self) -> u64 {
        (self.tail_end - self.p - 1) as u64
    }

    /// Static cost estimate in merge steps (for the scan binner):
    /// `min(segment length, tail length) + 1`. The kernel probes the
    /// *smaller* of the two sides, so its work is bounded by the
    /// shorter one — clamping by `tail_end - p - 1` stops the binner
    /// from overweighting long-partner segments behind short tails —
    /// and the `+ 1` counts the window-locate setup the kernel also
    /// counts. This is a true upper bound on the kernel-returned steps
    /// (verified by the step-invariant property tests).
    pub fn estimated_steps(&self) -> u64 {
        ((self.hi - self.lo) as u64).min(self.tail_len()) + 1
    }
}

/// Enumerate the segment-split task list of one support pass: for every
/// live slot `p` with a non-empty tail and non-empty partner row, one
/// [`SegTask`] per ≤`len`-entry segment of the partner row's live
/// entries. Slots whose merge is trivially empty (no tail, or empty
/// partner row) produce no tasks — they contribute no matches.
pub fn segment_tasks(z: &ZCsr, len: u32) -> Vec<SegTask> {
    let len = len.max(1) as usize;
    let col = z.col();
    let n = z.n();
    let live: Vec<u32> = (0..n).map(|i| z.row_live(i).len() as u32).collect();
    let mut tasks = Vec::new();
    for i in 0..n {
        let (start, _) = z.row_span(i);
        let li = live[i] as usize;
        let tail_end = (start + li) as u32;
        for off in 0..li {
            let p = start + off;
            if li - off - 1 == 0 {
                continue; // last live slot: empty tail, no merge work
            }
            let kappa = col[p] as usize;
            let lk = live[kappa] as usize;
            if lk == 0 {
                continue; // empty partner row, no merge work
            }
            let (r0, _) = z.row_span(kappa);
            let mut lo = 0usize;
            while lo < lk {
                let hi = (lo + len).min(lk);
                tasks.push(SegTask {
                    p: p as u32,
                    tail_end,
                    lo: (r0 + lo) as u32,
                    hi: (r0 + hi) as u32,
                });
                lo = hi;
            }
        }
    }
    tasks
}

/// The matching `(q, r)` pairs of one segment task, found by the
/// **side-adaptive probe** strategy: locate the tail window that can
/// match inside the segment (two lower-bound searches — the one counted
/// setup step), then iterate the *smaller* side and binary-search each
/// of its values in the other. Returns the executed step count:
/// `1 + min(window length, segment length)`, which the caller's
/// [`SegTask::estimated_steps`] bounds from above — the unified
/// step-accounting contract (setup counted, work clamped by the shorter
/// side) that replay calibration and measured-trace re-binning rely on.
///
/// The probe set equals the sorted-merge intersection of the tail with
/// the segment, so every `(q, r)` match pair is produced exactly once
/// and segmented passes stay byte-identical to the plain merge.
#[inline]
fn segment_probe(col: &[Vid], t: &SegTask, mut hit: impl FnMut(usize, usize)) -> u64 {
    let p = t.p as usize;
    let (lo, hi) = (t.lo as usize, t.hi as usize);
    let tail = &col[p + 1..t.tail_end as usize];
    let seg = &col[lo..hi];
    // setup (1 step): the tail window [q0, q1) whose values fall inside
    // the segment's value range — entries outside it cannot match here
    let q0 = tail.partition_point(|&c| c < seg[0]);
    let q1 = q0 + tail[q0..].partition_point(|&c| c <= seg[hi - lo - 1]);
    let mut steps = 1u64;
    if q1 - q0 <= hi - lo {
        for (off, w) in tail[q0..q1].iter().enumerate() {
            steps += 1;
            if let Ok(ri) = seg.binary_search(w) {
                hit(p + 1 + q0 + off, lo + ri);
            }
        }
    } else {
        for (ri, w) in seg.iter().enumerate() {
            steps += 1;
            if let Ok(off) = tail[q0..q1].binary_search(w) {
                hit(p + 1 + q0 + off, lo + ri);
            }
        }
    }
    steps
}

/// Eager update for one [`SegTask`], sequential support array. Returns
/// the executed steps (setup + probes, see [`segment_probe`]); always
/// `≤ t.estimated_steps()`.
#[inline]
pub fn eager_update_segment_seq(col: &[Vid], s: &mut [u32], t: &SegTask) -> u64 {
    let p = t.p as usize;
    segment_probe(col, t, |q, r| {
        s[p] += 1;
        s[q] += 1;
        s[r] += 1;
    })
}

/// Atomic variant of [`eager_update_segment_seq`] for the pool: segment
/// tasks of the *same* fine task race on `s[p]` (and on shared `S₂₂`
/// rows), so every bump is a relaxed fetch-add. Same step accounting as
/// the sequential kernel.
#[inline]
pub fn eager_update_segment_atomic(col: &[Vid], s: &[AtomicU32], t: &SegTask) -> u64 {
    let p = t.p as usize;
    segment_probe(col, t, |q, r| {
        s[p].fetch_add(1, Ordering::Relaxed);
        s[q].fetch_add(1, Ordering::Relaxed);
        s[r].fetch_add(1, Ordering::Relaxed);
    })
}

/// Sequential segment-split `computeSupports`: clears `s`, enumerates
/// the [`segment_tasks`] list and applies every segment merge. Returns
/// total merge steps (consumed by segment-overhead calibration). The
/// result is identical to [`compute_supports_seq`] — verified by the
/// segment property tests.
pub fn compute_supports_segmented_seq(z: &ZCsr, len: u32, s: &mut Vec<u32>) -> u64 {
    s.clear();
    s.resize(z.slots(), 0);
    let col = z.col();
    let mut steps = 0u64;
    for t in &segment_tasks(z, len) {
        steps += eager_update_segment_seq(col, s, t);
    }
    steps
}

/// Support slot values give the triangle count per live edge; the total
/// triangle count of the graph is `sum(S) / 3` (each triangle bumps
/// three slots).
pub fn total_triangles(s: &[u32]) -> u64 {
    s.iter().map(|&x| x as u64).sum::<u64>() / 3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_sorted_unique;
    use crate::graph::Csr;

    fn supports_of(g: &Csr) -> (ZCsr, Vec<u32>) {
        let z = ZCsr::from_csr(g);
        let mut s = Vec::new();
        compute_supports_seq(&z, &mut s);
        (z, s)
    }

    /// Collect (u, v, support) triples for live edges.
    fn edge_supports(z: &ZCsr, s: &[u32]) -> Vec<(u32, u32, u32)> {
        let mut out = Vec::new();
        for i in 0..z.n() {
            let (start, _) = z.row_span(i);
            for (off, &c) in z.row_live(i).iter().enumerate() {
                out.push((i as u32, c, s[start + off]));
            }
        }
        out
    }

    #[test]
    fn triangle_graph() {
        let g = from_sorted_unique(3, &[(0, 1), (0, 2), (1, 2)]);
        let (z, s) = supports_of(&g);
        let es = edge_supports(&z, &s);
        assert_eq!(es, vec![(0, 1, 1), (0, 2, 1), (1, 2, 1)]);
        assert_eq!(total_triangles(&s), 1);
    }

    #[test]
    fn diamond_graph() {
        // triangles {0,1,2} and {0,2,3}; edge (0,2) is in both
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let (z, s) = supports_of(&g);
        let es = edge_supports(&z, &s);
        assert_eq!(
            es,
            vec![(0, 1, 1), (0, 2, 2), (0, 3, 1), (1, 2, 1), (2, 3, 1)]
        );
        assert_eq!(total_triangles(&s), 2);
    }

    #[test]
    fn k4_every_edge_in_two_triangles() {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        let (z, s) = supports_of(&g);
        for (u, v, sup) in edge_supports(&z, &s) {
            assert_eq!(sup, 2, "edge ({u},{v})");
        }
        assert_eq!(total_triangles(&s), 4);
    }

    #[test]
    fn triangle_free_graph_zero_support() {
        // 5-cycle: no triangles
        let g = from_sorted_unique(5, &[(0, 1), (0, 4), (1, 2), (2, 3), (3, 4)]);
        let (_, s) = supports_of(&g);
        assert!(s.iter().all(|&x| x == 0));
    }

    #[test]
    fn optimized_kernel_matches_checked_kernel() {
        let g = crate::gen::rmat::rmat(
            400,
            3000,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(321),
        );
        let z = ZCsr::from_csr(&g);
        let mut fast = Vec::new();
        let steps_fast = compute_supports_seq(&z, &mut fast);
        let mut checked = Vec::new();
        let steps_checked = compute_supports_seq_checked(&z, &mut checked);
        assert_eq!(fast, checked);
        assert_eq!(steps_fast, steps_checked);
        // the returned totals are the exact traced step counts
        let mut s = Vec::new();
        let tr = crate::cost::trace::trace_supports(&z, &mut s);
        assert_eq!(steps_fast, tr.total_steps);
    }

    #[test]
    fn atomic_matches_seq() {
        let g = crate::gen::erdos_renyi::gnm(200, 1500, &mut crate::util::Rng::new(5));
        let z = ZCsr::from_csr(&g);
        let mut s_seq = Vec::new();
        compute_supports_seq(&z, &mut s_seq);

        let s_at: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
        let col = z.col();
        for i in 0..z.n() {
            let (start, end) = z.row_span(i);
            for p in start..end {
                let kappa = col[p];
                if kappa == 0 {
                    break;
                }
                let (r0, _) = z.row_span(kappa as usize);
                eager_update_atomic(col, &s_at, p, r0);
            }
        }
        let s_at_plain: Vec<u32> = s_at.iter().map(|x| x.load(Ordering::Relaxed)).collect();
        assert_eq!(s_seq, s_at_plain);
    }

    #[test]
    fn granularity_display_roundtrips_through_fromstr() {
        for g in [
            Granularity::Coarse,
            Granularity::Fine,
            Granularity::Segment { len: 64 },
            Granularity::Segment { len: 7 },
            Granularity::Hybrid { len: 64 },
            Granularity::Hybrid { len: 9 },
        ] {
            let s = g.to_string();
            let back: Granularity = s.parse().unwrap_or_else(|e| panic!("{s}: {e}"));
            assert_eq!(back, g, "{s}");
        }
        assert_eq!(
            "segment".parse::<Granularity>().unwrap(),
            Granularity::Segment { len: DEFAULT_SEGMENT_LEN }
        );
        assert_eq!(
            "hybrid".parse::<Granularity>().unwrap(),
            Granularity::Hybrid { len: DEFAULT_SEGMENT_LEN }
        );
        assert!("nope".parse::<Granularity>().is_err());
        assert!("segment:0".parse::<Granularity>().is_err());
        assert!("segment:x".parse::<Granularity>().is_err());
        assert!("hybrid:0".parse::<Granularity>().is_err());
        assert!("hybrid:x".parse::<Granularity>().is_err());
        assert_eq!(Granularity::from(Mode::Coarse).mode(), Some(Mode::Coarse));
        assert_eq!(Granularity::Segment { len: 4 }.mode(), None);
        assert_eq!(Granularity::Hybrid { len: 4 }.mode(), None);
        assert_eq!(Granularity::Segment { len: 4 }.short(), "S4");
        assert_eq!(Granularity::Hybrid { len: 4 }.short(), "H4");
    }

    #[test]
    fn segment_tasks_partition_partner_rows() {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let z = ZCsr::from_csr(&g);
        for len in [1u32, 2, 64] {
            let tasks = segment_tasks(&z, len);
            for t in &tasks {
                assert!(t.lo < t.hi, "{t:?}");
                assert!((t.hi - t.lo) <= len, "{t:?}");
                assert!((t.p as usize) + 1 < t.tail_end as usize, "{t:?}");
                assert!(t.estimated_steps() >= 1);
            }
            // segments of one fine task must partition its partner row:
            // group by p and check contiguity
            let mut by_p: std::collections::HashMap<u32, Vec<(u32, u32)>> =
                std::collections::HashMap::new();
            for t in &tasks {
                by_p.entry(t.p).or_default().push((t.lo, t.hi));
            }
            for (p, mut segs) in by_p {
                segs.sort_unstable();
                let kappa = z.col()[p as usize] as usize;
                let (r0, _) = z.row_span(kappa);
                let lk = z.row_live(kappa).len();
                assert_eq!(segs.first().unwrap().0 as usize, r0, "p={p}");
                assert_eq!(segs.last().unwrap().1 as usize, r0 + lk, "p={p}");
                for w in segs.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "p={p}: segments must be contiguous");
                }
            }
        }
    }

    #[test]
    fn segmented_supports_match_plain_on_fixtures() {
        let diamond = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let k4 = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        let rmat = crate::gen::rmat::rmat(
            300,
            2500,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(17),
        );
        for g in [&diamond, &k4, &rmat] {
            let z = ZCsr::from_csr(g);
            let mut want = Vec::new();
            compute_supports_seq(&z, &mut want);
            for len in [1u32, 2, 3, 64] {
                let mut got = Vec::new();
                compute_supports_segmented_seq(&z, len, &mut got);
                assert_eq!(got, want, "len={len}");
            }
        }
    }

    #[test]
    fn segmented_pass_on_empty_and_star_graphs() {
        // triangle-free star: hub row is hot but every partner row is
        // empty, so the task list is empty and all supports stay 0
        let mut edges = Vec::new();
        for v in 1..50u32 {
            edges.push((0, v));
        }
        let star = from_sorted_unique(50, &edges);
        let z = ZCsr::from_csr(&star);
        assert!(segment_tasks(&z, 8).is_empty());
        let mut s = Vec::new();
        let steps = compute_supports_segmented_seq(&z, 8, &mut s);
        assert_eq!(steps, 0);
        assert!(s.iter().all(|&x| x == 0));
        // empty graph
        let z = ZCsr::from_csr(&crate::graph::Csr::empty(0));
        let mut s = Vec::new();
        compute_supports_segmented_seq(&z, 8, &mut s);
        assert!(s.is_empty());
    }

    #[test]
    fn steps_equal_merge_work() {
        // rows [1,2,3,0] and [3,0]: slot of (0,1) merges tail [2,3] with
        // row1 [2? no — row 1 holds [2..]]. Just sanity: steps > 0 when
        // both sides non-empty.
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let z = ZCsr::from_csr(&g);
        let mut s = vec![0u32; z.slots()];
        let steps = row_task_seq(&z, &mut s, 0);
        // (0,1): merge [2,3] vs [2] = 1 step; (0,2): [3] vs [3] = 1 step;
        // (0,3): empty tail = 0 steps
        assert_eq!(steps, 2);
        // row 3 has no entries -> no work
        let steps3 = row_task_seq(&z, &mut s, 3);
        assert_eq!(steps3, 0);
    }
}
