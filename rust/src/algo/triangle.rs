//! Independent triangle counting over the canonical CSR. Deliberately
//! does *not* share code with the eager support kernel — it is the
//! cross-check oracle for `sum(S)/3`.

use crate::graph::Csr;

/// Count triangles by rank-ordered neighborhood intersection:
/// for each edge (u, v) with u < v, count common neighbors w > v.
/// Each triangle (u < v < w) is counted exactly once.
pub fn count_triangles(g: &Csr) -> u64 {
    let mut total = 0u64;
    for u in 0..g.n() {
        let row_u = g.row(u);
        for (j, &v) in row_u.iter().enumerate() {
            let tail = &row_u[j + 1..];
            let row_v = g.row(v as usize);
            total += sorted_intersection_count(tail, row_v);
        }
    }
    total
}

/// Per-edge triangle participation (support) computed independently:
/// returns, for each row-major live edge index, its triangle count.
/// O(m · d_max); used only as a test oracle.
pub fn edge_supports_naive(g: &Csr) -> Vec<u32> {
    // index of each edge (u,v) in row-major order
    let mut sup = vec![0u32; g.nnz()];
    let edge_index = |u: usize, v: u32| -> Option<usize> {
        let row = g.row(u);
        row.binary_search(&v).ok().map(|off| g.row_ptr()[u] as usize + off)
    };
    for u in 0..g.n() {
        let row_u = g.row(u);
        for (j, &v) in row_u.iter().enumerate() {
            for &w in &row_u[j + 1..] {
                // triangle (u, v, w) iff edge (v, w) exists
                if g.has_edge(v, w) {
                    let e_uv = edge_index(u, v).unwrap();
                    let e_uw = edge_index(u, w).unwrap();
                    let e_vw = edge_index(v.min(w) as usize, v.max(w)).unwrap();
                    sup[e_uv] += 1;
                    sup[e_uw] += 1;
                    sup[e_vw] += 1;
                }
            }
        }
    }
    sup
}

#[inline]
fn sorted_intersection_count(a: &[u32], b: &[u32]) -> u64 {
    let (mut i, mut j, mut c) = (0usize, 0usize, 0u64);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                c += 1;
                i += 1;
                j += 1;
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_sorted_unique;

    #[test]
    fn counts_match_known_graphs() {
        // triangle
        let t = from_sorted_unique(3, &[(0, 1), (0, 2), (1, 2)]);
        assert_eq!(count_triangles(&t), 1);
        // K4 has 4 triangles
        let k4 = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        assert_eq!(count_triangles(&k4), 4);
        // K5 has C(5,3)=10
        let mut edges = Vec::new();
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push((u, v));
            }
        }
        let k5 = from_sorted_unique(5, &edges);
        assert_eq!(count_triangles(&k5), 10);
        // 6-cycle: none
        let c6 = from_sorted_unique(6, &[(0, 1), (0, 5), (1, 2), (2, 3), (3, 4), (4, 5)]);
        assert_eq!(count_triangles(&c6), 0);
    }

    #[test]
    fn naive_supports_sum_to_three_times_triangles() {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let sup = edge_supports_naive(&g);
        assert_eq!(sup.iter().map(|&x| x as u64).sum::<u64>(), 3 * 2);
        assert_eq!(sup, vec![1, 2, 1, 1, 1]);
    }

    #[test]
    fn agrees_with_eager_kernel_on_random_graph() {
        use crate::algo::support::{compute_supports_seq, total_triangles};
        use crate::graph::ZCsr;
        let g = crate::gen::rmat::rmat(
            300,
            2000,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(9),
        );
        let z = ZCsr::from_csr(&g);
        let mut s = Vec::new();
        compute_supports_seq(&z, &mut s);
        assert_eq!(total_triangles(&s), count_triangles(&g));
        // per-edge agreement
        let naive = edge_supports_naive(&g);
        let mut eager = Vec::with_capacity(g.nnz());
        for i in 0..z.n() {
            let (start, _) = z.row_span(i);
            for off in 0..z.row_live(i).len() {
                eager.push(s[start + off]);
            }
        }
        assert_eq!(naive, eager);
    }
}
