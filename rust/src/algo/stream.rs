//! Streaming mutations: batched edge inserts/deletes applied to a
//! resident graph whose supports **and** k-truss are maintained rather
//! than rebuilt — the Hornet/cuStinger `BatchUpdate` shape on top of
//! the incremental frontier kernels of [`super::incremental`].
//!
//! [`StreamState`] owns the working form of the *current* graph (every
//! live edge, not just the truss) with exact per-slot supports. One
//! [`EdgeBatch`] flows through:
//!
//! 1. **Normalize** — orient each pair upper-triangular, reject
//!    self-loops, out-of-range endpoints, in-batch duplicates, deletes
//!    of absent edges and inserts of present ones (presence is judged
//!    against the pre-batch graph, so an insert+delete of the same
//!    edge in one batch keeps the delete and rejects the insert).
//! 2. **Delete pass** — mark the doomed slots, enumerate the destroyed
//!    triangles with the deletion frontier kernel, decrement the
//!    surviving legs, compact preserving supports.
//! 3. **Insert pass** — rebuild the working form with the new edges
//!    spliced in (row capacities are fixed, so insertion is a
//!    copy-on-compact rebuild), carry every survivor's support to its
//!    new slot, then enumerate the *new* triangles with the insertion
//!    frontier kernel, incrementing all three legs.
//! 4. **Truss maintenance** — a sound fast-path check skips
//!    re-convergence entirely when no deleted edge was in the old
//!    truss and every inserted edge's post-increment support is below
//!    `k - 2` (such an insert cannot join the truss, and any new
//!    triangle it forms contains it, so it cannot re-admit old edges
//!    either). Otherwise the truss is re-derived by a **warm**
//!    incremental convergence seeded from the maintained supports —
//!    the bounded re-admission scan: the dominant initial full pass is
//!    skipped, and only the cascade rounds run.
//!
//! Both passes run sequentially ([`StreamState::apply`]) or on the
//! pool under an [`ExecutionPlan`] ([`StreamState::apply_par`]); the
//! two are bit-identical by the seq↔par parity of the frontier
//! kernels. The epoch-versioned wrapper for concurrent readers is
//! [`GraphStore`](crate::serve::store::GraphStore).

use crate::algo::incremental::{
    compact_preserving, decrement_frontier_seq, frontier_from_marked, increment_frontier_seq,
    InNbrs, SupportMode, DEFAULT_CROSSOVER_FRAC,
};
use crate::algo::ktruss::run_to_convergence_plan;
use crate::graph::builder::from_sorted_unique;
use crate::graph::zeroterm::ZCsr;
use crate::graph::{Csr, Vid};
use crate::par::{PassControl, Pool};
use crate::plan::ExecutionPlan;
use crate::util::bitset::BitSet;
use std::collections::HashSet;
use std::sync::atomic::{AtomicU32, Ordering};

/// One batch of edge mutations, as submitted (unoriented, unvalidated
/// — [`StreamState::apply`] normalizes and rejects bad entries).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EdgeBatch {
    /// Edges to insert.
    pub insert: Vec<(Vid, Vid)>,
    /// Edges to delete.
    pub delete: Vec<(Vid, Vid)>,
}

impl EdgeBatch {
    /// An insert-only batch.
    pub fn inserts(edges: Vec<(Vid, Vid)>) -> EdgeBatch {
        EdgeBatch { insert: edges, delete: Vec::new() }
    }

    /// A delete-only batch.
    pub fn deletes(edges: Vec<(Vid, Vid)>) -> EdgeBatch {
        EdgeBatch { insert: Vec::new(), delete: edges }
    }

    /// Total submitted mutations (before normalization).
    pub fn len(&self) -> usize {
        self.insert.len() + self.delete.len()
    }

    /// Whether the batch carries no mutations at all.
    pub fn is_empty(&self) -> bool {
        self.insert.is_empty() && self.delete.is_empty()
    }
}

/// What one applied batch did, with exact step accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatchOutcome {
    /// Edges inserted after normalization.
    pub inserted: usize,
    /// Edges deleted after normalization.
    pub deleted: usize,
    /// Submitted mutations rejected by normalization.
    pub rejected: usize,
    /// Exact steps of the delete + insert frontier passes.
    pub frontier_steps: u64,
    /// Exact steps of the truss re-convergence (0 on the fast path).
    pub converge_steps: u64,
    /// Whether the truss was re-derived (the fast path skipped it).
    pub recomputed: bool,
    /// Edges in the maintained k-truss after the batch.
    pub truss_edges: usize,
}

/// Orient `(a, b)` upper-triangular, rejecting self-loops and
/// out-of-range endpoints.
fn orient(a: Vid, b: Vid, n: usize) -> Option<(Vid, Vid)> {
    if a == b || a as usize >= n || b as usize >= n {
        return None;
    }
    Some((a.min(b), a.max(b)))
}

/// The maintained streaming state: current graph, exact supports, and
/// the k-truss at a fixed `k`.
#[derive(Clone, Debug)]
pub struct StreamState {
    k: u32,
    /// Working form of the current graph (all live edges).
    z: ZCsr,
    /// Exact per-slot supports of `z`.
    s: Vec<u32>,
    /// CSR snapshot of `z` (refreshed after every mutating batch).
    graph: Csr,
    /// The maintained k-truss of `graph`.
    truss: Csr,
}

impl StreamState {
    /// Start streaming from `g`, computing initial supports and the
    /// initial k-truss.
    pub fn new(g: &Csr, k: u32) -> StreamState {
        let z = ZCsr::from_csr(g);
        let mut s = Vec::new();
        crate::algo::support::compute_supports_seq(&z, &mut s);
        let mut z2 = z.clone();
        let mut s2 = s.clone();
        run_to_convergence_plan(
            &mut z2,
            &mut s2,
            k,
            SupportMode::Incremental,
            DEFAULT_CROSSOVER_FRAC,
            true,
        );
        StreamState { k, z, s, graph: g.clone(), truss: z2.to_csr() }
    }

    /// The fixed truss order this state maintains.
    pub fn k(&self) -> u32 {
        self.k
    }

    /// The current graph.
    pub fn graph(&self) -> &Csr {
        &self.graph
    }

    /// The maintained k-truss of the current graph.
    pub fn truss(&self) -> &Csr {
        &self.truss
    }

    /// The maintained per-slot supports. The working form is kept
    /// canonical after every batch, so the layout (and the values)
    /// equal a fresh [`ZCsr::from_csr`]`(self.graph())` recompute.
    pub fn supports(&self) -> &[u32] {
        &self.s
    }

    /// Apply one batch sequentially.
    pub fn apply(&mut self, batch: &EdgeBatch) -> BatchOutcome {
        self.apply_impl(batch, None, PassControl::default()).0
    }

    /// Apply one batch with the frontier passes on the pool under
    /// `plan` (granularity + schedule). Bit-identical to [`apply`]
    /// (same outcome, same step counts) by the kernels' seq↔par
    /// parity; the truss re-convergence stays sequential — it is the
    /// exactness anchor, and the frontier passes are the hot part.
    ///
    /// [`apply`]: StreamState::apply
    pub fn apply_par(
        &mut self,
        batch: &EdgeBatch,
        pool: &Pool,
        plan: &ExecutionPlan,
    ) -> BatchOutcome {
        self.apply_impl(batch, Some((pool, plan)), PassControl::default()).0
    }

    /// [`apply_par`] with cooperative cancellation checked at the
    /// stage boundaries of the batch pipeline (before the delete pass,
    /// between delete and insert, and before the warm truss
    /// re-convergence). Returns the outcome of the work performed plus
    /// whether the batch was cut short.
    ///
    /// A cancelled application leaves the state **partially mutated**
    /// (whichever stages already ran are committed); callers needing
    /// all-or-nothing semantics must apply to a clone and swap on
    /// success, which is exactly what
    /// [`GraphStore`](crate::serve::store::GraphStore) does.
    ///
    /// [`apply_par`]: StreamState::apply_par
    pub fn apply_par_ctl(
        &mut self,
        batch: &EdgeBatch,
        pool: &Pool,
        plan: &ExecutionPlan,
        ctl: PassControl<'_>,
    ) -> (BatchOutcome, bool) {
        self.apply_impl(batch, Some((pool, plan)), ctl)
    }

    fn apply_impl(
        &mut self,
        batch: &EdgeBatch,
        par: Option<(&Pool, &ExecutionPlan)>,
        ctl: PassControl<'_>,
    ) -> (BatchOutcome, bool) {
        let n = self.z.n();
        let mut rejected = 0usize;
        let mut seen: HashSet<(Vid, Vid)> = HashSet::with_capacity(batch.len());
        let mut dels: Vec<(Vid, Vid)> = Vec::new();
        for &(a, b) in &batch.delete {
            match orient(a, b, n) {
                Some(e) if seen.insert(e) && self.graph.has_edge(e.0, e.1) => dels.push(e),
                _ => rejected += 1,
            }
        }
        let mut ins: Vec<(Vid, Vid)> = Vec::new();
        for &(a, b) in &batch.insert {
            match orient(a, b, n) {
                Some(e) if seen.insert(e) && !self.graph.has_edge(e.0, e.1) => ins.push(e),
                _ => rejected += 1,
            }
        }

        let mut frontier_steps = 0u64;
        // the fast-path evidence, gathered before the truss moves
        let old_truss_hit = dels.iter().any(|&(u, v)| self.truss.has_edge(u, v));

        // stage boundary 0: before any mutation — a cancel here is a
        // pure no-op on the state
        let mut cancelled = ctl.pass_boundary(0);
        let mut applied_dels = 0usize;
        let mut applied_ins = 0usize;

        if !cancelled && !dels.is_empty() {
            applied_dels = dels.len();
            let mut marked = BitSet::new(self.z.slots());
            for &(u, v) in &dels {
                let (start, _) = self.z.row_span(u as usize);
                let j = self
                    .z
                    .row_live(u as usize)
                    .binary_search(&v)
                    .expect("normalized delete is present");
                marked.set(start + j);
            }
            let f = frontier_from_marked(&self.z, &marked);
            let in_nbrs = InNbrs::build(&self.z);
            match par {
                Some((pool, plan)) => {
                    let s_at: Vec<AtomicU32> =
                        self.s.iter().map(|&x| AtomicU32::new(x)).collect();
                    frontier_steps += crate::par::frontier::decrement_frontier_par_gran(
                        &self.z,
                        pool,
                        &f,
                        &in_nbrs,
                        plan.granularity,
                        plan.schedule,
                        &s_at,
                        None,
                    );
                    crate::par::frontier::compact_preserving_par(
                        &mut self.z,
                        &s_at,
                        &f.dying,
                        pool,
                        plan.schedule,
                    );
                    for (dst, src) in self.s.iter_mut().zip(&s_at) {
                        *dst = src.load(Ordering::Relaxed);
                    }
                }
                None => {
                    frontier_steps += decrement_frontier_seq(&self.z, &mut self.s, &f, &in_nbrs);
                    compact_preserving(&mut self.z, &mut self.s, &f.dying);
                }
            }
        }

        // stage boundary 1: between the delete and insert passes —
        // a cancel here commits the deletes and skips the rest
        if !cancelled && ctl.pass_boundary(1) {
            cancelled = true;
        }

        let mut max_inserted_support = 0u32;
        if !cancelled && !ins.is_empty() {
            applied_ins = ins.len();
            // copy-on-compact rebuild: row capacities of the working
            // form are fixed, so insertion reconstructs it from the
            // surviving live edges plus the batch
            let mut edges: Vec<(Vid, Vid)> = Vec::with_capacity(self.z.live_edges() + ins.len());
            for i in 0..n {
                for &v in self.z.row_live(i) {
                    edges.push((i as Vid, v));
                }
            }
            edges.extend(ins.iter().copied());
            edges.sort_unstable();
            let g_new = from_sorted_unique(n, &edges);
            let z_new = ZCsr::from_csr(&g_new);
            // splice every survivor's maintained support into its new
            // slot; slots with no old counterpart are the inserted set
            let mut s_new = vec![0u32; z_new.slots()];
            let mut inserted = BitSet::new(z_new.slots());
            for i in 0..n {
                let (ns, _) = z_new.row_span(i);
                let (os, _) = self.z.row_span(i);
                let old_row = self.z.row_live(i);
                let mut oj = 0usize;
                for (j, &c) in z_new.row_live(i).iter().enumerate() {
                    if oj < old_row.len() && old_row[oj] == c {
                        s_new[ns + j] = self.s[os + oj];
                        oj += 1;
                    } else {
                        inserted.set(ns + j);
                    }
                }
                debug_assert_eq!(oj, old_row.len(), "old row {i} must survive the rebuild");
            }
            let f = frontier_from_marked(&z_new, &inserted);
            let in_nbrs = InNbrs::build(&z_new);
            match par {
                Some((pool, plan)) => {
                    let s_at: Vec<AtomicU32> =
                        s_new.iter().map(|&x| AtomicU32::new(x)).collect();
                    frontier_steps += crate::par::frontier::increment_frontier_par_gran(
                        &z_new,
                        pool,
                        &f,
                        &in_nbrs,
                        plan.granularity,
                        plan.schedule,
                        &s_at,
                        None,
                    );
                    for (dst, src) in s_new.iter_mut().zip(&s_at) {
                        *dst = src.load(Ordering::Relaxed);
                    }
                }
                None => {
                    frontier_steps += increment_frontier_seq(&z_new, &mut s_new, &f, &in_nbrs);
                }
            }
            for t in &f.tasks {
                max_inserted_support = max_inserted_support.max(s_new[t.p as usize]);
            }
            self.z = z_new;
            self.s = s_new;
        }

        let mutated = applied_dels > 0 || applied_ins > 0;
        if mutated {
            self.graph = self.z.to_csr();
            if applied_ins == 0 {
                // deletes compact within the old row capacities; rebuild
                // the working form canonically so the slot layout always
                // equals `ZCsr::from_csr(graph)` (the supports contract —
                // the insert pass re-canonicalizes as a side effect)
                let z_new = ZCsr::from_csr(&self.graph);
                let mut s_new = vec![0u32; z_new.slots()];
                for i in 0..n {
                    let (ns, _) = z_new.row_span(i);
                    let (os, _) = self.z.row_span(i);
                    let len = z_new.row_live(i).len();
                    s_new[ns..ns + len].copy_from_slice(&self.s[os..os + len]);
                }
                self.z = z_new;
                self.s = s_new;
            }
        }

        // fast path: deleting non-truss edges cannot shrink the truss
        // (it survives in G - D and stays maximal), and an inserted
        // edge below the support threshold cannot join it — nor
        // re-admit anything, since every triangle it creates contains
        // it. Anything else re-derives the truss by warm incremental
        // convergence from the maintained supports (the re-admission
        // scan: the initial full pass is skipped, only cascade rounds
        // run).
        let threshold = self.k.saturating_sub(2);
        let ins_hit = applied_ins > 0 && max_inserted_support >= threshold;
        let mut converge_steps = 0u64;
        let mut recomputed = false;
        if !cancelled && mutated && (old_truss_hit || ins_hit) {
            // stage boundary 2: before the warm re-convergence — a
            // cancel here keeps graph + supports exact and leaves only
            // the maintained truss stale
            if ctl.pass_boundary(2) {
                cancelled = true;
            } else {
                recomputed = true;
                let mut z2 = self.z.clone();
                let mut s2 = self.s.clone();
                let (_iters, stats) = run_to_convergence_plan(
                    &mut z2,
                    &mut s2,
                    self.k,
                    SupportMode::Incremental,
                    DEFAULT_CROSSOVER_FRAC,
                    true,
                );
                converge_steps = stats.iter().map(|st| st.support_steps).sum();
                self.truss = z2.to_csr();
            }
        }

        (
            BatchOutcome {
                inserted: applied_ins,
                deleted: applied_dels,
                rejected,
                frontier_steps,
                converge_steps,
                recomputed,
                truss_edges: self.truss.nnz(),
            },
            cancelled,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::incremental::SupportMode;
    use crate::algo::ktruss::ktruss_mode;
    use crate::algo::support::{compute_supports_seq, Mode};

    /// Maintained state must equal a from-scratch derivation on the
    /// mutated graph: exact supports, identical truss.
    fn assert_matches_scratch(st: &StreamState, ctx: &str) {
        let z = ZCsr::from_csr(st.graph());
        let mut want = Vec::new();
        compute_supports_seq(&z, &mut want);
        assert_eq!(st.supports(), &want[..], "{ctx}: maintained supports diverged");
        let scratch = ktruss_mode(st.graph(), st.k(), Mode::Fine, SupportMode::Full);
        assert_eq!(st.truss(), &scratch.truss, "{ctx}: maintained truss diverged");
    }

    #[test]
    fn delete_then_reinsert_restores_the_state() {
        let g = crate::gen::rmat::rmat(
            200,
            1400,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(11),
        );
        let mut st = StreamState::new(&g, 4);
        let initial_truss = st.truss().clone();
        let victims: Vec<(Vid, Vid)> =
            g.edges().enumerate().filter(|(i, _)| i % 7 == 0).map(|(_, e)| e).collect();
        let out = st.apply(&EdgeBatch::deletes(victims.clone()));
        assert_eq!(out.deleted, victims.len());
        assert_eq!(out.rejected, 0);
        assert_matches_scratch(&st, "after delete");
        let n_victims = victims.len();
        let out = st.apply(&EdgeBatch::inserts(victims));
        assert_eq!(out.inserted, n_victims);
        assert_matches_scratch(&st, "after reinsert");
        assert_eq!(st.graph(), &g, "graph must round-trip");
        assert_eq!(st.truss(), &initial_truss, "truss must round-trip");
    }

    #[test]
    fn rejections_are_counted_and_ignored() {
        let g = crate::graph::builder::from_sorted_unique(4, &[(0, 1), (0, 2), (1, 2)]);
        let mut st = StreamState::new(&g, 3);
        let before = st.graph().clone();
        let out = st.apply(&EdgeBatch {
            // self-loop, present edge, duplicate pair (reversed), out of range
            insert: vec![(1, 1), (0, 1), (1, 3), (3, 1), (0, 9)],
            // absent edge
            delete: vec![(0, 3)],
        });
        assert_eq!(out.inserted, 1, "only (1,3) is insertable");
        assert_eq!(out.deleted, 0);
        assert_eq!(out.rejected, 5);
        assert_matches_scratch(&st, "after rejects");
        assert_ne!(st.graph(), &before);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let g = crate::graph::Csr::diamond();
        let mut st = StreamState::new(&g, 3);
        let before = st.clone();
        let out = st.apply(&EdgeBatch::default());
        assert_eq!(out.frontier_steps, 0);
        assert_eq!(out.converge_steps, 0);
        assert!(!out.recomputed);
        assert_eq!(st.graph(), before.graph());
        assert_eq!(st.truss(), before.truss());
        assert_eq!(st.supports(), before.supports());
    }

    #[test]
    fn fast_path_skips_reconvergence_when_sound() {
        // diamond + pendant: the pendant edge is not in the 3-truss,
        // so deleting it must take the fast path
        let g = crate::graph::builder::from_sorted_unique(
            5,
            &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3), (3, 4)],
        );
        let mut st = StreamState::new(&g, 3);
        let out = st.apply(&EdgeBatch::deletes(vec![(3, 4)]));
        assert!(!out.recomputed, "non-truss delete must not re-converge");
        assert_eq!(out.converge_steps, 0);
        assert_matches_scratch(&st, "after pendant delete");
        // re-inserting it creates zero triangles: fast path again
        let out = st.apply(&EdgeBatch::inserts(vec![(3, 4)]));
        assert!(!out.recomputed, "zero-triangle insert must not re-converge");
        assert_matches_scratch(&st, "after pendant reinsert");
        // deleting a truss edge must re-converge
        let out = st.apply(&EdgeBatch::deletes(vec![(0, 2)]));
        assert!(out.recomputed);
        assert_matches_scratch(&st, "after truss delete");
    }

    #[test]
    fn cancelled_apply_commits_only_completed_stages() {
        use crate::algo::support::Granularity;
        use crate::par::{CancelToken, PassControl, Pool, Schedule};
        use crate::plan::ExecutionPlan;
        let g = crate::gen::erdos_renyi::gnm(120, 700, &mut crate::util::Rng::new(29));
        let mut st = StreamState::new(&g, 4);
        let pool = Pool::new(2);
        let plan = ExecutionPlan::fixed(Schedule::Static, Granularity::Fine, SupportMode::Full);
        let dels: Vec<(Vid, Vid)> = g.edges().step_by(5).collect();

        // pre-cancelled: stage boundary 0 fires, nothing moves
        let tok = CancelToken::new();
        tok.cancel();
        let before = st.clone();
        let (out, cancelled) = st.apply_par_ctl(
            &EdgeBatch::deletes(dels.clone()),
            &pool,
            &plan,
            PassControl { cancel: Some(&tok), on_pass: None },
        );
        assert!(cancelled, "pre-cancelled token must cut the batch short");
        assert_eq!(out.deleted, 0, "cancel before stage 0 must commit nothing");
        assert!(!out.recomputed);
        assert_eq!(st.graph(), before.graph());
        assert_eq!(st.truss(), before.truss());
        assert_eq!(st.supports(), before.supports());

        // cancel fired by the stage hook *after* the delete pass: the
        // deletes commit (graph + supports exact), the truss stays stale
        let tok = CancelToken::new();
        let hook = |stage: usize| {
            if stage == 1 {
                tok.cancel();
            }
        };
        let (out, cancelled) = st.apply_par_ctl(
            &EdgeBatch::deletes(dels.clone()),
            &pool,
            &plan,
            PassControl { cancel: Some(&tok), on_pass: Some(&hook) },
        );
        assert!(cancelled);
        assert_eq!(out.deleted, dels.len(), "completed delete stage must be reported");
        assert!(!out.recomputed, "cancel must skip the re-convergence");
        let z = ZCsr::from_csr(st.graph());
        let mut want = Vec::new();
        crate::algo::support::compute_supports_seq(&z, &mut want);
        assert_eq!(st.supports(), &want[..], "committed stages must stay exact");
        assert_eq!(st.truss(), before.truss(), "truss must be untouched (stale)");

        // an uncancelled ctl run equals the plain parallel path
        let mut a = before.clone();
        let mut b = before.clone();
        let (out_a, cancelled) =
            a.apply_par_ctl(&EdgeBatch::deletes(dels.clone()), &pool, &plan, PassControl::default());
        let out_b = b.apply_par(&EdgeBatch::deletes(dels), &pool, &plan);
        assert!(!cancelled);
        assert_eq!(out_a, out_b);
        assert_eq!(a.graph(), b.graph());
        assert_eq!(a.truss(), b.truss());
    }

    #[test]
    fn mixed_batch_applies_deletes_before_inserts() {
        let g = crate::gen::erdos_renyi::gnm(120, 700, &mut crate::util::Rng::new(29));
        let mut st = StreamState::new(&g, 4);
        let all: Vec<(Vid, Vid)> = g.edges().collect();
        let dels: Vec<(Vid, Vid)> = all.iter().copied().step_by(9).collect();
        // inserts of currently-absent pairs
        let mut ins = Vec::new();
        let mut rng = crate::util::Rng::new(31);
        while ins.len() < 20 {
            let u = rng.below(119) as Vid;
            let v = (u + 1 + rng.below((120 - u as u64).saturating_sub(1).max(1)) as Vid).min(119);
            if u != v && !g.has_edge(u, v) && !ins.contains(&(u, v)) {
                ins.push((u, v));
            }
        }
        let out = st.apply(&EdgeBatch { insert: ins.clone(), delete: dels.clone() });
        assert_eq!(out.deleted, dels.len());
        assert_eq!(out.inserted, ins.len());
        assert_matches_scratch(&st, "after mixed batch");
    }
}
