//! `pruneEdges` — Step 2 of the Eager K-truss algorithm:
//! `M = S ≥ (k-2); A = A ∘ M`.
//!
//! Realized on the zero-terminated CSR by compacting each row's
//! survivors to the front and zero-filling the tail — the paper's
//! early-termination trick: the next support pass stops at the first
//! zero, so pruned rows get cheaper, and the representation needs no
//! extra bookkeeping (§III-D).

use crate::graph::zeroterm::ZCsr;

/// Result of one prune pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PruneOutcome {
    /// Edges removed this pass. 0 ⇒ `isUnchanged(M)` ⇒ converged.
    pub removed: usize,
    /// Live edges remaining after the pass.
    pub remaining: usize,
}

/// Prune every edge with support `< k - 2`, compacting rows in place.
/// `s` is consumed (reset to zero) so the next iteration starts clean.
pub fn prune(z: &mut ZCsr, s: &mut [u32], k: u32) -> PruneOutcome {
    assert_eq!(s.len(), z.slots());
    let threshold = k.saturating_sub(2);
    let mut removed = 0usize;
    let mut remaining = 0usize;
    for i in 0..z.n() {
        let (start, end) = z.row_span(i);
        let col = z.col_mut();
        let mut write = start;
        for p in start..end {
            let c = col[p];
            if c == 0 {
                break; // tail already dead
            }
            if s[p] >= threshold {
                col[write] = c;
                write += 1;
            } else {
                removed += 1;
            }
        }
        remaining += write - start;
        // zero-fill the rest of the row (tombstones + terminator)
        for slot in col.iter_mut().take(end).skip(write) {
            *slot = 0;
        }
        // reset supports for the whole row span
        for sp in s.iter_mut().take(end).skip(start) {
            *sp = 0;
        }
    }
    PruneOutcome { removed, remaining }
}

/// Count how many live edges *would* be pruned at threshold `k` without
/// mutating anything (used by the coordinator's progress estimates).
pub fn count_below(z: &ZCsr, s: &[u32], k: u32) -> usize {
    let threshold = k.saturating_sub(2);
    let mut below = 0usize;
    for i in 0..z.n() {
        let (start, _) = z.row_span(i);
        for (off, &c) in z.row_raw(i).iter().enumerate() {
            if c == 0 {
                break;
            }
            if s[start + off] < threshold {
                below += 1;
            }
        }
    }
    below
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::support::compute_supports_seq;
    use crate::graph::builder::from_sorted_unique;
    use crate::graph::validate;

    #[test]
    fn prune_removes_low_support_edges() {
        // diamond + pendant edge (3,4): pendant has support 0
        let g = from_sorted_unique(5, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3), (3, 4)]);
        let mut z = ZCsr::from_csr(&g);
        let mut s = Vec::new();
        compute_supports_seq(&z, &mut s);
        let out = prune(&mut z, &mut s, 3); // threshold 1
        assert_eq!(out.removed, 1);
        assert_eq!(out.remaining, 5);
        assert!(validate::check_zcsr(&z).is_ok());
        assert_eq!(z.row_live(3), &[] as &[u32]); // (3,4) gone
        // supports were reset
        assert!(s.iter().all(|&x| x == 0));
    }

    #[test]
    fn prune_k3_keeps_triangles() {
        let g = from_sorted_unique(3, &[(0, 1), (0, 2), (1, 2)]);
        let mut z = ZCsr::from_csr(&g);
        let mut s = Vec::new();
        compute_supports_seq(&z, &mut s);
        let out = prune(&mut z, &mut s, 3);
        assert_eq!(out.removed, 0);
        assert_eq!(out.remaining, 3);
    }

    #[test]
    fn prune_high_k_removes_everything() {
        let g = from_sorted_unique(3, &[(0, 1), (0, 2), (1, 2)]);
        let mut z = ZCsr::from_csr(&g);
        let mut s = Vec::new();
        compute_supports_seq(&z, &mut s);
        let out = prune(&mut z, &mut s, 4); // needs 2 triangles per edge
        assert_eq!(out.removed, 3);
        assert_eq!(out.remaining, 0);
        assert!(validate::check_zcsr(&z).is_ok());
    }

    #[test]
    fn count_below_matches_prune() {
        let g = from_sorted_unique(5, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3), (3, 4)]);
        let z0 = ZCsr::from_csr(&g);
        let mut s = Vec::new();
        compute_supports_seq(&z0, &mut s);
        let predicted = count_below(&z0, &s, 3);
        let mut z = z0.clone();
        let out = prune(&mut z, &mut s, 3);
        assert_eq!(predicted, out.removed);
    }

    #[test]
    fn compaction_preserves_sorted_order() {
        // row 0: [1,2,3,4]; kill (0,2) and keep rest sorted
        let g = from_sorted_unique(
            6,
            &[(0, 1), (0, 2), (0, 3), (0, 4), (1, 3), (3, 4), (1, 4)],
        );
        let mut z = ZCsr::from_csr(&g);
        let mut s = vec![0u32; z.slots()];
        // hand-craft supports: give everything 5 except slot of (0,2)
        for i in 0..z.n() {
            let (start, _) = z.row_span(i);
            for (off, &c) in z.row_live(i).iter().enumerate() {
                s[start + off] = if (i, c) == (0, 2) { 0 } else { 5 };
            }
        }
        prune(&mut z, &mut s, 3);
        assert_eq!(z.row_live(0), &[1, 3, 4]);
        assert!(validate::check_zcsr(&z).is_ok());
    }
}
