//! Full truss decomposition: the *trussness* of every edge — the largest
//! k such that the edge survives in the k-truss. Generalizes the single-k
//! query; the coordinator exposes it as a job type and the examples use
//! it to report community structure.

use super::incremental::SupportMode;
use super::ktruss::run_to_convergence_mode;
use crate::graph::{Csr, Vid, ZCsr};
use std::collections::HashMap;

/// Trussness assignment for every edge of the input graph.
#[derive(Clone, Debug)]
pub struct Decomposition {
    /// `(u, v) -> trussness`, for every input edge (u < v). Edges in no
    /// triangle get trussness 2.
    pub trussness: HashMap<(Vid, Vid), u32>,
    /// Largest k with non-empty truss.
    pub kmax: u32,
}

impl Decomposition {
    /// The k-truss edge set implied by the decomposition.
    pub fn truss_edges(&self, k: u32) -> Vec<(Vid, Vid)> {
        let mut es: Vec<(Vid, Vid)> = self
            .trussness
            .iter()
            .filter(|&(_, &t)| t >= k)
            .map(|(&e, _)| e)
            .collect();
        es.sort_unstable();
        es
    }

    /// Histogram: for each k in 2..=kmax, how many edges have exactly
    /// that trussness.
    pub fn histogram(&self) -> Vec<(u32, usize)> {
        let mut counts: HashMap<u32, usize> = HashMap::new();
        for &t in self.trussness.values() {
            *counts.entry(t).or_insert(0) += 1;
        }
        let mut v: Vec<(u32, usize)> = counts.into_iter().collect();
        v.sort_unstable();
        v
    }
}

/// Peel k upward; an edge's trussness is `k-1` where k is the first
/// level that removed it (edges surviving to the end get `kmax`).
pub fn decompose(g: &Csr) -> Decomposition {
    let mut trussness: HashMap<(Vid, Vid), u32> = g.edges().map(|e| (e, 2)).collect();
    if g.nnz() == 0 {
        return Decomposition { trussness, kmax: 0 };
    }
    let mut z = ZCsr::from_csr(g);
    let mut s: Vec<u32> = Vec::new();
    let mut prev_edges: Vec<(Vid, Vid)> = g.edges().collect();
    let mut kmax = 2u32;
    let mut k = 3u32;
    let mut warm = false;
    loop {
        // warm re-entry: each k-level reuses the supports the previous
        // level's convergence left behind (see `algo::kmax`)
        run_to_convergence_mode(&mut z, &mut s, k, SupportMode::Auto, warm);
        warm = true;
        let cur = z.to_csr();
        let cur_edges: std::collections::HashSet<(Vid, Vid)> = cur.edges().collect();
        // edges alive at k-1 but not at k have trussness k-1
        for &e in &prev_edges {
            if !cur_edges.contains(&e) {
                trussness.insert(e, k - 1);
            }
        }
        if cur_edges.is_empty() {
            break;
        }
        kmax = k;
        for &e in &cur_edges {
            trussness.insert(e, k);
        }
        prev_edges = cur.edges().collect();
        k += 1;
    }
    Decomposition { trussness, kmax }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::ktruss::{ktruss, Mode};
    use crate::graph::builder::from_sorted_unique;

    #[test]
    fn clique_plus_tail() {
        // K4 {0..3} + path 3-4-5
        let g = from_sorted_unique(
            6,
            &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3), (3, 4), (4, 5)],
        );
        let d = decompose(&g);
        assert_eq!(d.kmax, 4);
        assert_eq!(d.trussness[&(0, 1)], 4);
        assert_eq!(d.trussness[&(2, 3)], 4);
        assert_eq!(d.trussness[&(3, 4)], 2);
        assert_eq!(d.trussness[&(4, 5)], 2);
    }

    #[test]
    fn histogram_sums_to_edge_count() {
        let g = crate::gen::community::communities(150, 800, 15, &mut crate::util::Rng::new(41));
        let d = decompose(&g);
        let total: usize = d.histogram().iter().map(|&(_, c)| c).sum();
        assert_eq!(total, g.nnz());
    }

    #[test]
    fn truss_edges_match_direct_computation() {
        let g = crate::gen::rmat::rmat(
            150,
            900,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(43),
        );
        let d = decompose(&g);
        for k in 3..=d.kmax {
            let direct = ktruss(&g, k, Mode::Fine);
            let from_decomp = d.truss_edges(k);
            let direct_edges: Vec<(Vid, Vid)> = direct.truss.edges().collect();
            assert_eq!(from_decomp, direct_edges, "k={k}");
        }
    }

    #[test]
    fn kmax_agrees_with_kmax_module() {
        let g = crate::gen::community::communities(120, 600, 12, &mut crate::util::Rng::new(47));
        assert_eq!(decompose(&g).kmax, crate::algo::kmax::kmax(&g).kmax);
    }
}
