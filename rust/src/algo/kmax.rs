//! `K_max` — the largest k with a non-empty k-truss (the paper's second
//! experimental setting). Exploits the nesting `truss(k+1) ⊆ truss(k)`:
//! we walk k upward, re-running the convergence loop *on the already
//! pruned graph*, so each step only strips the newly sub-threshold
//! edges. The convergence driver leaves the maintained support array
//! valid whenever live edges remain, so every k-level after the first
//! re-enters **warm** — no full support recompute per level (see
//! [`run_to_convergence_mode`]).

use super::incremental::SupportMode;
use super::ktruss::{run_to_convergence_mode, IterationStat};
use crate::graph::{Csr, ZCsr};

/// Result of the `K_max` search.
#[derive(Clone, Debug)]
pub struct KmaxResult {
    /// Largest k whose k-truss is non-empty (≥ 2 by convention: the
    /// 2-truss is the whole graph once isolated... a graph with any edge
    /// has k_max ≥ 2; triangle-free graphs have k_max == 2).
    pub kmax: u32,
    /// The k_max-truss subgraph.
    pub truss: Csr,
    /// Total support+prune iterations summed over all k steps (what a
    /// timing simulation replays).
    pub total_iterations: usize,
    /// Per-k iteration stats: (k, stats-of-that-k's-loop).
    pub per_k: Vec<(u32, Vec<IterationStat>)>,
}

/// Compute `K_max` and its truss by incremental peeling.
pub fn kmax(g: &Csr) -> KmaxResult {
    if g.nnz() == 0 {
        return KmaxResult { kmax: 0, truss: Csr::empty(g.n()), total_iterations: 0, per_k: Vec::new() };
    }
    let mut z = ZCsr::from_csr(g);
    let mut s: Vec<u32> = Vec::new();
    let mut last_nonempty = z.to_csr();
    let mut kmax = 2u32;
    let mut total_iterations = 0usize;
    let mut per_k = Vec::new();
    let mut k = 3u32;
    let mut warm = false;
    loop {
        let (iters, stats) =
            run_to_convergence_mode(&mut z, &mut s, k, SupportMode::Auto, warm);
        // the driver leaves s valid for the survivors on every non-empty
        // exit, so the next k-level skips its initial full pass
        warm = true;
        total_iterations += iters;
        per_k.push((k, stats));
        if z.live_edges() == 0 {
            break;
        }
        kmax = k;
        last_nonempty = z.to_csr();
        k += 1;
    }
    KmaxResult { kmax, truss: last_nonempty, total_iterations, per_k }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_sorted_unique;

    #[test]
    fn kmax_of_clique() {
        // K_n is an n-truss (every edge in n-2 triangles)
        for n in [3u32, 4, 5, 6] {
            let mut edges = Vec::new();
            for u in 0..n {
                for v in (u + 1)..n {
                    edges.push((u, v));
                }
            }
            let g = from_sorted_unique(n as usize, &edges);
            let r = kmax(&g);
            assert_eq!(r.kmax, n, "K{n}");
            assert_eq!(r.truss.nnz() as u32, n * (n - 1) / 2);
        }
    }

    #[test]
    fn kmax_of_triangle_free_is_two() {
        let g = from_sorted_unique(5, &[(0, 1), (0, 4), (1, 2), (2, 3), (3, 4)]);
        let r = kmax(&g);
        assert_eq!(r.kmax, 2);
        // the 2-truss is the full (cycle) graph
        assert_eq!(r.truss.nnz(), 5);
    }

    #[test]
    fn kmax_of_empty_graph() {
        let g = Csr::empty(4);
        assert_eq!(kmax(&g).kmax, 0);
    }

    #[test]
    fn kmax_finds_embedded_clique() {
        // K5 plus a long tail: kmax = 5 from the clique
        let mut edges = Vec::new();
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push((u, v));
            }
        }
        edges.extend_from_slice(&[(4, 5), (5, 6), (6, 7)]);
        let g = from_sorted_unique(8, &edges);
        let r = kmax(&g);
        assert_eq!(r.kmax, 5);
        assert_eq!(r.truss.nnz(), 10);
    }

    #[test]
    fn kmax_truss_matches_direct_ktruss() {
        use crate::algo::ktruss::{ktruss, Mode};
        let g = crate::gen::community::communities(200, 1200, 20, &mut crate::util::Rng::new(3));
        let r = kmax(&g);
        let direct = ktruss(&g, r.kmax, Mode::Fine);
        assert_eq!(r.truss, direct.truss);
        // and one higher k is empty
        assert!(ktruss(&g, r.kmax + 1, Mode::Fine).is_empty());
    }
}
