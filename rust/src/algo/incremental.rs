//! Incremental, frontier-driven support maintenance — the PKT idea
//! (Kabir & Madduri, *Shared-memory Graph Truss Decomposition*) applied
//! to the Eager K-truss convergence loop.
//!
//! The full driver recomputes `S = AᵀA ∘ A` over every live edge each
//! iteration, so a cascade that prunes 1% of the edges per round still
//! pays 100% of the merge work per round. This module replaces the
//! recompute with an exact *update*: when a batch `D` of edges dies,
//! every triangle of the pre-prune graph that contains a dying edge is
//! destroyed, and each **surviving** edge of such a triangle loses
//! exactly one support. After the update, `S` equals what a full
//! recompute on the pruned graph would produce — slot for slot.
//!
//! ## Triangle enumeration over the zero-terminated CSR
//!
//! A triangle `(a, b, c)` with `a < b < c` occupies three slots of the
//! upper-triangular working form: `p_ab` (edge `a–b`, in row `a`),
//! `p_ac` (edge `a–c`, in row `a`, after `p_ab`), and `p_bc` (edge
//! `b–c`, in row `b`). The flat slot order is therefore always
//! `p_ab < p_ac < p_bc`. A dying edge can sit in any of the three
//! positions, and each position has its own enumeration:
//!
//! * **ab** — the dying edge spans the two smallest endpoints: the
//!   standard eager merge of row `a`'s live tail after `p_ab` against
//!   row `b` finds every `c` (exactly the forward intersection the full
//!   kernel runs).
//! * **ac** — the dying edge spans the smallest and largest endpoint:
//!   `b` ranges over row `a`'s live entries *before* `p_ac`; each
//!   candidate is confirmed by a binary search for `c` in row `b`.
//! * **bc** — the dying edge spans the two largest endpoints: `a` ranges
//!   over the in-neighbors of `b` (or of `c`, whichever list is
//!   shorter), confirmed by binary searches for `b` and `c` in row `a`.
//!   In-neighbors come from a one-time [`InNbrs`] index built from the
//!   graph at loop entry; stale entries (edges pruned since) simply
//!   fail the search and are skipped.
//!
//! ## Exactly-once attribution
//!
//! A destroyed triangle may contain one, two or three dying edges; its
//! surviving legs must be decremented exactly once. The triangle is
//! *attributed* to its lowest-slot dying edge: the `ab` enumeration
//! always claims the triangle when `p_ab` dies; the `ac` enumeration
//! skips candidates whose `ab` slot is dying; the `bc` enumeration
//! skips candidates whose `ab` or `ac` slot is dying. Dying legs are
//! never decremented (their slots are compacted away immediately
//! after). Dying status is a snapshot taken before any decrement, so
//! a survivor whose support drops below the threshold mid-update is
//! still treated as a survivor this round — it dies *next* round,
//! exactly as in the full driver.
//!
//! ## Insertion mirror
//!
//! Batched edge *insertions* run the same three enumerations on the
//! **post-insertion** working form, with the frontier's mark set
//! holding the inserted slots instead of the dying ones. Every
//! triangle of the new graph that contains an inserted edge is a *new*
//! triangle, and all three of its legs gain one support — the inserted
//! legs included, since their supports are built up from zero by
//! exactly these triangles. Attribution is identical: the triangle is
//! claimed by its lowest-slot inserted edge, so triangles with two or
//! three inserted legs are still counted exactly once. After the pass,
//! the maintained array equals a full recompute on the new graph, slot
//! for slot ([`increment_task_seq`], [`increment_frontier_seq`], and
//! the pool variant in [`par::frontier`](crate::par::frontier)).
//!
//! ## Cost accounting
//!
//! Every kernel returns exact step counts (merge compares + binary
//! search probes + candidate scans), so `IterationStat.support_steps`,
//! the replay tracer and the simulators stay truthful, and
//! [`frontier_costs`] produces per-task upper bounds the work-aware
//! binner and the [`crossover`] heuristic consume.

use crate::graph::zeroterm::ZCsr;
use crate::graph::Vid;
use crate::util::bitset::BitSet;
use std::sync::atomic::{AtomicU32, Ordering};

/// How the convergence loop maintains the support array across
/// iterations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SupportMode {
    /// Recompute `S = AᵀA ∘ A` from scratch every iteration (the
    /// original Eager K-truss loop).
    Full,
    /// After the first full pass, update `S` by decrementing only the
    /// triangles destroyed by each iteration's pruned-edge frontier.
    Incremental,
    /// Per-iteration choice: run the frontier update when its estimated
    /// work is below [`DEFAULT_CROSSOVER_FRAC`] of the full-pass
    /// estimate, fall back to the full recompute otherwise.
    Auto,
}

impl SupportMode {
    /// Whether this mode ever runs the frontier update (and therefore
    /// needs the [`InNbrs`] index).
    pub fn allows_incremental(self) -> bool {
        !matches!(self, SupportMode::Full)
    }
}

impl std::fmt::Display for SupportMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SupportMode::Full => write!(f, "full"),
            SupportMode::Incremental => write!(f, "incremental"),
            SupportMode::Auto => write!(f, "auto"),
        }
    }
}

impl std::str::FromStr for SupportMode {
    type Err = String;

    /// Parse `full`, `incremental` (or `inc`), `auto` — the CLI
    /// `--support-mode` grammar.
    fn from_str(s: &str) -> Result<SupportMode, String> {
        match s {
            "full" => Ok(SupportMode::Full),
            "incremental" | "inc" => Ok(SupportMode::Incremental),
            "auto" => Ok(SupportMode::Auto),
            other => Err(format!(
                "unknown support mode {other:?} (expected full|incremental|auto)"
            )),
        }
    }
}

/// Default crossover fraction of [`SupportMode::Auto`]: the frontier
/// update runs only when its estimated work is at most this fraction of
/// the full-pass proxy (conservative, because both sides are upper
/// bounds with different slack). The fraction itself now lives in the
/// [`ExecutionPlan`](crate::plan::ExecutionPlan) — every driver receives
/// it from its plan, and this constant is only the value plans default
/// to.
pub const DEFAULT_CROSSOVER_FRAC: f64 = 0.5;

/// In-neighbor index over the upper-triangular working form: for every
/// vertex `v`, the rows `a < v` whose row contained `v` **at build
/// time**, ascending. The graph only shrinks under pruning, so the
/// lists are a superset of the live in-neighbors forever; consumers
/// re-validate each entry with a binary search on the current row (a
/// pruned edge fails the search and is skipped).
#[derive(Clone, Debug)]
pub struct InNbrs {
    /// `offsets[v]..offsets[v+1]` spans `src` for vertex `v`.
    offsets: Vec<u32>,
    /// Concatenated in-neighbor lists (row indices), ascending per
    /// vertex.
    src: Vec<Vid>,
}

impl InNbrs {
    /// Build the index from the current live entries of `z` (one
    /// `O(nnz)` scan).
    pub fn build(z: &ZCsr) -> InNbrs {
        let n = z.n();
        let mut offsets = vec![0u32; n + 1];
        for i in 0..n {
            for &v in z.row_live(i) {
                offsets[v as usize + 1] += 1;
            }
        }
        for v in 0..n {
            offsets[v + 1] += offsets[v];
        }
        let mut cursor: Vec<u32> = offsets[..n].to_vec();
        let mut src = vec![0 as Vid; offsets[n] as usize];
        for i in 0..n {
            for &v in z.row_live(i) {
                let c = &mut cursor[v as usize];
                src[*c as usize] = i as Vid;
                *c += 1;
            }
        }
        InNbrs { offsets, src }
    }

    /// The (possibly stale) in-neighbor list of `v`, ascending.
    #[inline]
    pub fn of(&self, v: usize) -> &[Vid] {
        &self.src[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }

    /// List length for `v` (for cost estimates).
    #[inline]
    pub fn len_of(&self, v: usize) -> usize {
        (self.offsets[v + 1] - self.offsets[v]) as usize
    }
}

/// One frontier task: a dying edge, identified by its row and flat slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrontierTask {
    /// Row (smaller endpoint) of the dying edge.
    pub row: u32,
    /// Flat slot index of the dying edge.
    pub p: u32,
}

/// The pruned-edge frontier of one iteration, plus the snapshots the
/// update kernels need.
#[derive(Clone, Debug)]
pub struct Frontier {
    /// One task per dying edge, in ascending slot order.
    pub tasks: Vec<FrontierTask>,
    /// Per-slot dying snapshot (bit set ⇒ the slot is pruned this
    /// round). One bit per slot (`len() == z.slots()`) — the
    /// byte-per-slot mask this replaced cost 8x the memory traffic on
    /// the three hot membership probes of every enumeration.
    pub dying: BitSet,
    /// Live entries per row of the *pre-prune* graph (dying edges
    /// included) — the bounds every enumeration walks.
    pub live: Vec<u32>,
}

impl Frontier {
    /// Number of dying edges.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// Whether the iteration converged (nothing to prune).
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
}

/// Scan the support array and collect the dying frontier at threshold
/// `k - 2`: every live slot whose support is below it. Reads supports
/// through `get` so both the plain and the atomic drivers share the
/// scan.
pub fn mark_frontier_with(z: &ZCsr, k: u32, get: impl Fn(usize) -> u32) -> Frontier {
    let threshold = k.saturating_sub(2);
    let col = z.col();
    let n = z.n();
    let mut tasks = Vec::new();
    let mut dying = BitSet::new(z.slots());
    let mut live = vec![0u32; n];
    for i in 0..n {
        let (start, end) = z.row_span(i);
        for p in start..end {
            if col[p] == 0 {
                break;
            }
            live[i] += 1;
            if get(p) < threshold {
                dying.set(p);
                tasks.push(FrontierTask { row: i as u32, p: p as u32 });
            }
        }
    }
    Frontier { tasks, dying, live }
}

/// [`mark_frontier_with`] over a plain support array.
pub fn mark_frontier(z: &ZCsr, s: &[u32], k: u32) -> Frontier {
    debug_assert_eq!(s.len(), z.slots());
    mark_frontier_with(z, k, |p| s[p])
}

/// Build a [`Frontier`] from an explicit per-slot mark set — batch
/// mutations pick their own slots, so the threshold scan of
/// [`mark_frontier`] does not apply. Live counts come from the current
/// working form (marked slots included) and tasks come out in
/// ascending slot order, exactly as the scan would produce them. For a
/// deletion batch the marks are the doomed slots of the pre-delete
/// form; for an insertion batch they are the inserted slots of the
/// post-insert form.
pub fn frontier_from_marked(z: &ZCsr, marked: &BitSet) -> Frontier {
    assert_eq!(marked.len(), z.slots());
    let col = z.col();
    let n = z.n();
    let mut tasks = Vec::new();
    let mut live = vec![0u32; n];
    for i in 0..n {
        let (start, end) = z.row_span(i);
        for p in start..end {
            if col[p] == 0 {
                break;
            }
            live[i] += 1;
            if marked.get(p) {
                tasks.push(FrontierTask { row: i as u32, p: p as u32 });
            }
        }
    }
    Frontier { tasks, dying: marked.clone(), live }
}

/// Binary search `v` in the live region of `row` (`len` live entries),
/// counting probes into `steps`. Returns the flat slot on a hit.
#[inline]
fn find_slot(
    col: &[Vid],
    start: usize,
    len: usize,
    v: Vid,
    steps: &mut u64,
) -> Option<usize> {
    let mut lo = 0usize;
    let mut hi = len;
    while lo < hi {
        let mid = (lo + hi) / 2;
        *steps += 1;
        match col[start + mid].cmp(&v) {
            std::cmp::Ordering::Less => lo = mid + 1,
            std::cmp::Ordering::Greater => hi = mid,
            std::cmp::Ordering::Equal => return Some(start + mid),
        }
    }
    None
}

/// Apply one frontier task against a plain support array: enumerate
/// every destroyed triangle attributed to this dying edge and decrement
/// its surviving legs. Returns exact steps (merge compares + search
/// probes + candidate scans).
pub fn frontier_task_seq(
    z: &ZCsr,
    s: &mut [u32],
    f: &Frontier,
    in_nbrs: &InNbrs,
    t: FrontierTask,
) -> u64 {
    let mut steps = 0u64;
    frontier_task_impl(
        z,
        f,
        in_nbrs,
        t,
        &mut steps,
        |slot| {
            debug_assert!(s[slot] > 0, "support underflow at slot {slot}");
            s[slot] -= 1;
        },
    );
    steps
}

/// Atomic variant of [`frontier_task_seq`] for the worker pool:
/// concurrent frontier tasks may decrement the same surviving slot, so
/// every bump is a relaxed `fetch_sub` (decrements are commutative and
/// `S` is read only after the pass, exactly as in the full kernel).
pub fn frontier_task_atomic(
    z: &ZCsr,
    s: &[AtomicU32],
    f: &Frontier,
    in_nbrs: &InNbrs,
    t: FrontierTask,
) -> u64 {
    let mut steps = 0u64;
    frontier_task_impl(z, f, in_nbrs, t, &mut steps, |slot| {
        s[slot].fetch_sub(1, Ordering::Relaxed);
    });
    steps
}

/// Shared enumeration body: `dec(slot)` performs one support decrement.
#[inline]
fn frontier_task_impl(
    z: &ZCsr,
    f: &Frontier,
    in_nbrs: &InNbrs,
    t: FrontierTask,
    steps: &mut u64,
    mut dec: impl FnMut(usize),
) {
    let col = z.col();
    let dying = &f.dying;
    let live = &f.live[..];
    let u = t.row as usize;
    let p = t.p as usize;
    let v = col[p] as usize;
    debug_assert!(v != 0, "frontier task on a dead slot");
    let (u_start, _) = z.row_span(u);
    let u_end = u_start + live[u] as usize;
    let (v_start, _) = z.row_span(v);
    let v_end = v_start + live[v] as usize;

    // position ab: merge the live tail after p with row v — every match
    // w closes triangle (u, v, w), always attributed here
    let mut q = p + 1;
    let mut r = v_start;
    while q < u_end && r < v_end {
        *steps += 1;
        match col[q].cmp(&col[r]) {
            std::cmp::Ordering::Less => q += 1,
            std::cmp::Ordering::Greater => r += 1,
            std::cmp::Ordering::Equal => {
                if !dying.get(q) {
                    dec(q);
                }
                if !dying.get(r) {
                    dec(r);
                }
                q += 1;
                r += 1;
            }
        }
    }

    // position ac: b ranges over row u's live prefix before p; the
    // triangle (u, b, v) is attributed here unless its ab slot dies too
    for pb in u_start..p {
        *steps += 1;
        if dying.get(pb) {
            continue; // lower-slot dying edge claims the triangle
        }
        let b = col[pb] as usize;
        let (b_start, _) = z.row_span(b);
        if let Some(r) = find_slot(col, b_start, live[b] as usize, v as Vid, steps) {
            dec(pb); // ab leg, known surviving
            if !dying.get(r) {
                dec(r);
            }
        }
    }

    // position bc: a ranges over the shorter in-neighbor list of u or v
    // (entries are stale-tolerant; both legs are re-validated on the
    // current rows); attributed here only when both other legs survive
    let iu = in_nbrs.of(u);
    let iv = in_nbrs.of(v);
    // candidates must satisfy a < u; iv is ascending, so cut it there
    let iv_cut = iv.partition_point(|&a| (a as usize) < u);
    if iu.len() <= iv_cut {
        for &a in iu {
            *steps += 1;
            let a = a as usize;
            let (a_start, _) = z.row_span(a);
            let Some(pa) = find_slot(col, a_start, live[a] as usize, u as Vid, steps) else {
                continue; // edge (a, u) pruned in an earlier round
            };
            if dying.get(pa) {
                continue;
            }
            let Some(pav) = find_slot(col, a_start, live[a] as usize, v as Vid, steps) else {
                continue;
            };
            if dying.get(pav) {
                continue;
            }
            dec(pa);
            dec(pav);
        }
    } else {
        for &a in &iv[..iv_cut] {
            *steps += 1;
            let a = a as usize;
            let (a_start, _) = z.row_span(a);
            let Some(pav) = find_slot(col, a_start, live[a] as usize, v as Vid, steps) else {
                continue;
            };
            let Some(pa) = find_slot(col, a_start, live[a] as usize, u as Vid, steps) else {
                continue;
            };
            if dying.get(pa) || dying.get(pav) {
                continue;
            }
            dec(pa);
            dec(pav);
        }
    }
}

/// Run the whole frontier update sequentially. Returns total steps.
pub fn decrement_frontier_seq(
    z: &ZCsr,
    s: &mut [u32],
    f: &Frontier,
    in_nbrs: &InNbrs,
) -> u64 {
    let mut total = 0u64;
    for &t in &f.tasks {
        total += frontier_task_seq(z, s, f, in_nbrs, t);
    }
    total
}

/// **Fused** mark+decrement sweep, sequential reference: scan the
/// support array for sub-threshold slots and apply their decrement
/// enumerations in the same pass, instead of a mark kernel followed by
/// a decrement kernel. The result (frontier and supports) is identical
/// to [`mark_frontier`] + [`decrement_frontier_seq`] — decrements read
/// the completed dying snapshot either way — so the fusion buys
/// *launches and reads*, not different answers. Returns the frontier
/// plus the fused sweep's step count: the threshold scan (one step per
/// pre-prune live slot) plus the decrement enumerations. A separate
/// mark-then-decrement pair pays [`separate_mark_decrement_steps`] —
/// larger by exactly one re-read per marked task, plus (on a real
/// device) a second kernel launch. This is the accounting convention
/// the lane backend's incremental path reports
/// ([`crate::exec::lane::LaneRunReport`]).
pub fn fused_mark_decrement_seq(
    z: &ZCsr,
    s: &mut [u32],
    k: u32,
    in_nbrs: &InNbrs,
) -> (Frontier, u64) {
    let f = mark_frontier(z, s, k);
    let dec = decrement_frontier_seq(z, s, &f, in_nbrs);
    let scan: u64 = f.live.iter().map(|&x| u64::from(x)).sum();
    (f, scan + dec)
}

/// Step count of the same round executed as **separate** mark and
/// decrement launches: the threshold scan, plus the decrement kernel
/// re-reading each marked task, plus the decrement enumerations
/// (`dec_steps`). Exceeds the fused sweep's count by exactly
/// `f.len()`.
pub fn separate_mark_decrement_steps(f: &Frontier, dec_steps: u64) -> u64 {
    let scan: u64 = f.live.iter().map(|&x| u64::from(x)).sum();
    scan + f.len() as u64 + dec_steps
}

/// [`decrement_frontier_seq`] that also records each task's exact step
/// count (for the replay tracer and the simulators). Returns
/// `(total, per_task_steps)`.
pub fn decrement_frontier_traced(
    z: &ZCsr,
    s: &mut [u32],
    f: &Frontier,
    in_nbrs: &InNbrs,
) -> (u64, Vec<u32>) {
    let mut total = 0u64;
    let mut per_task = Vec::with_capacity(f.tasks.len());
    for &t in &f.tasks {
        let st = frontier_task_seq(z, s, f, in_nbrs, t);
        per_task.push(st.min(u32::MAX as u64) as u32);
        total += st;
    }
    (total, per_task)
}

/// Apply one insertion task against a plain support array: enumerate
/// every **new** triangle attributed to this inserted edge and
/// increment all three legs — the inserted legs included, since their
/// supports are built up from zero by exactly these triangles. Runs on
/// the *post-insertion* working form, with the frontier's mark set
/// holding the inserted slots ([`frontier_from_marked`]). Returns
/// exact steps, counted identically to the deletion kernel.
pub fn increment_task_seq(
    z: &ZCsr,
    s: &mut [u32],
    f: &Frontier,
    in_nbrs: &InNbrs,
    t: FrontierTask,
) -> u64 {
    let mut steps = 0u64;
    increment_task_impl(z, f, in_nbrs, t, &mut steps, |slot| {
        s[slot] += 1;
    });
    steps
}

/// Atomic variant of [`increment_task_seq`] for the worker pool:
/// concurrent insertion tasks may increment the same slot, so every
/// bump is a relaxed `fetch_add` (increments are commutative and `S`
/// is read only after the pass).
pub fn increment_task_atomic(
    z: &ZCsr,
    s: &[AtomicU32],
    f: &Frontier,
    in_nbrs: &InNbrs,
    t: FrontierTask,
) -> u64 {
    let mut steps = 0u64;
    increment_task_impl(z, f, in_nbrs, t, &mut steps, |slot| {
        s[slot].fetch_add(1, Ordering::Relaxed);
    });
    steps
}

/// Shared insertion enumeration body, the exact mirror of
/// [`frontier_task_impl`]: same three positions, same attribution to
/// the lowest marked slot, but every claimed triangle bumps all three
/// legs (`inc(slot)` performs one support increment).
#[inline]
fn increment_task_impl(
    z: &ZCsr,
    f: &Frontier,
    in_nbrs: &InNbrs,
    t: FrontierTask,
    steps: &mut u64,
    mut inc: impl FnMut(usize),
) {
    let col = z.col();
    let inserted = &f.dying;
    let live = &f.live[..];
    let u = t.row as usize;
    let p = t.p as usize;
    let v = col[p] as usize;
    debug_assert!(v != 0, "insertion task on a dead slot");
    let (u_start, _) = z.row_span(u);
    let u_end = u_start + live[u] as usize;
    let (v_start, _) = z.row_span(v);
    let v_end = v_start + live[v] as usize;

    // position ab: merge the live tail after p with row v — every match
    // w closes the new triangle (u, v, w), always attributed here
    let mut q = p + 1;
    let mut r = v_start;
    while q < u_end && r < v_end {
        *steps += 1;
        match col[q].cmp(&col[r]) {
            std::cmp::Ordering::Less => q += 1,
            std::cmp::Ordering::Greater => r += 1,
            std::cmp::Ordering::Equal => {
                inc(p);
                inc(q);
                inc(r);
                q += 1;
                r += 1;
            }
        }
    }

    // position ac: b ranges over row u's live prefix before p; the new
    // triangle (u, b, v) is attributed here unless its ab slot was also
    // inserted (the lower slot claims it)
    for pb in u_start..p {
        *steps += 1;
        if inserted.get(pb) {
            continue;
        }
        let b = col[pb] as usize;
        let (b_start, _) = z.row_span(b);
        if let Some(r) = find_slot(col, b_start, live[b] as usize, v as Vid, steps) {
            inc(pb);
            inc(p);
            inc(r);
        }
    }

    // position bc: a ranges over the shorter in-neighbor list of u or v
    // (the index is built from the post-insertion form, so entries are
    // exact, but both legs are still resolved on the current rows);
    // attributed here only when neither other leg was inserted
    let iu = in_nbrs.of(u);
    let iv = in_nbrs.of(v);
    let iv_cut = iv.partition_point(|&a| (a as usize) < u);
    if iu.len() <= iv_cut {
        for &a in iu {
            *steps += 1;
            let a = a as usize;
            let (a_start, _) = z.row_span(a);
            let Some(pa) = find_slot(col, a_start, live[a] as usize, u as Vid, steps) else {
                continue;
            };
            if inserted.get(pa) {
                continue;
            }
            let Some(pav) = find_slot(col, a_start, live[a] as usize, v as Vid, steps) else {
                continue;
            };
            if inserted.get(pav) {
                continue;
            }
            inc(pa);
            inc(pav);
            inc(p);
        }
    } else {
        for &a in &iv[..iv_cut] {
            *steps += 1;
            let a = a as usize;
            let (a_start, _) = z.row_span(a);
            let Some(pav) = find_slot(col, a_start, live[a] as usize, v as Vid, steps) else {
                continue;
            };
            let Some(pa) = find_slot(col, a_start, live[a] as usize, u as Vid, steps) else {
                continue;
            };
            if inserted.get(pa) || inserted.get(pav) {
                continue;
            }
            inc(pa);
            inc(pav);
            inc(p);
        }
    }
}

/// Run the whole insertion update sequentially. Returns total steps.
pub fn increment_frontier_seq(
    z: &ZCsr,
    s: &mut [u32],
    f: &Frontier,
    in_nbrs: &InNbrs,
) -> u64 {
    let mut total = 0u64;
    for &t in &f.tasks {
        total += increment_task_seq(z, s, f, in_nbrs, t);
    }
    total
}

/// Compact every row by dropping the dying slots, moving each
/// survivor's **support along with its column** (the whole point of the
/// incremental pass: supports are maintained, not reset). Dead tails
/// are zero-filled in both arrays. Returns the prune outcome.
pub fn compact_preserving(
    z: &mut ZCsr,
    s: &mut [u32],
    dying: &BitSet,
) -> crate::algo::prune::PruneOutcome {
    assert_eq!(s.len(), z.slots());
    assert_eq!(dying.len(), z.slots());
    let mut removed = 0usize;
    let mut remaining = 0usize;
    for i in 0..z.n() {
        let (start, end) = z.row_span(i);
        let col = z.col_mut();
        let mut write = start;
        for p in start..end {
            let c = col[p];
            if c == 0 {
                break;
            }
            if dying.get(p) {
                removed += 1;
            } else {
                col[write] = c;
                s[write] = s[p];
                write += 1;
            }
        }
        remaining += write - start;
        for slot in col.iter_mut().take(end).skip(write) {
            *slot = 0;
        }
        for sp in s.iter_mut().take(end).skip(write) {
            *sp = 0;
        }
    }
    crate::algo::prune::PruneOutcome { removed, remaining }
}

/// The binary-search probe bound for one frontier: a search over
/// ≤ `lmax` live entries probes at most `floor(log2(lmax)) + 1` times.
#[inline]
fn probe_bound(f: &Frontier) -> u64 {
    let lmax = f.live.iter().copied().max().unwrap_or(0);
    (u32::BITS - lmax.leading_zeros()) as u64 + 1
}

/// Upper bound on one frontier task's steps, in the same units the
/// kernels count: merge compares (tail + partner), prefix candidates
/// with one bounded binary search each, and in-neighbor candidates with
/// two.
#[inline]
fn frontier_task_cost(z: &ZCsr, f: &Frontier, in_nbrs: &InNbrs, probe: u64, t: FrontierTask) -> u64 {
    let col = z.col();
    let u = t.row as usize;
    let p = t.p as usize;
    let v = col[p] as usize;
    let (u_start, _) = z.row_span(u);
    let tail = (u_start + f.live[u] as usize - (p + 1)) as u64;
    let partner = f.live[v] as u64;
    let prefix = (p - u_start) as u64;
    let cand = in_nbrs.len_of(u).min(in_nbrs.len_of(v)) as u64;
    1 + tail + partner + prefix * (1 + probe) + cand * (1 + 2 * probe)
}

/// Per-task upper bounds on the frontier update's steps (see
/// [`frontier_task_cost`]'s terms). Feeds the work-aware binner and,
/// summed, the [`crossover`] heuristic.
pub fn frontier_costs(z: &ZCsr, f: &Frontier, in_nbrs: &InNbrs) -> Vec<u64> {
    let probe = probe_bound(f);
    f.tasks
        .iter()
        .map(|&t| frontier_task_cost(z, f, in_nbrs, probe, t))
        .collect()
}

/// Sum of [`frontier_costs`] without materializing the per-task vector
/// — what the sequential drivers (and any pool run under a
/// cost-oblivious schedule) feed the [`crossover`]; they never need the
/// per-task breakdown, so the auto check stops allocating a cost vector
/// every round.
pub fn frontier_costs_sum(z: &ZCsr, f: &Frontier, in_nbrs: &InNbrs) -> u64 {
    let probe = probe_bound(f);
    f.tasks
        .iter()
        .map(|&t| frontier_task_cost(z, f, in_nbrs, probe, t))
        .sum()
}

/// Upper bound on one full support pass over the current working form
/// (the same static bound the work-aware binner uses, summed without
/// allocating the per-task vector).
pub fn full_pass_estimate(z: &ZCsr) -> u64 {
    crate::par::balance::estimate_costs_sum(z, crate::algo::support::Mode::Fine)
}

/// The auto-mode crossover: run the frontier update when its estimated
/// work is at most `frac` of the full-pass proxy. The proxy is the
/// smaller of the static full-pass bound on the *current* (pre-compact)
/// form and the measured steps of the most recent full pass — both
/// upper-bound what a recompute would cost, with different slack.
pub fn crossover(frontier_est: u64, full_est: u64, last_full_steps: u64, frac: f64) -> bool {
    let proxy = full_est.min(last_full_steps).max(1);
    (frontier_est as f64) <= frac * proxy as f64
}

/// The per-round driver decision, shared by **every** convergence loop
/// (sequential, pooled coarse/fine, pooled segment, and the replay
/// tracer — one implementation, so the simulators' replay can never
/// desynchronize from the decisions production makes): should this
/// round's support update run incrementally?
///
/// `frac` is the crossover fraction the caller's
/// [`ExecutionPlan`](crate::plan::ExecutionPlan) carries
/// ([`DEFAULT_CROSSOVER_FRAC`] unless a plan overrode it). When
/// `want_costs` is set (a work-aware schedule will bin the frontier),
/// the [`SupportMode::Auto`] check hands back the per-task frontier
/// estimates it computed so the binner can reuse them; otherwise the
/// check runs through the allocation-free [`frontier_costs_sum`].
pub fn decide_incremental(
    z: &ZCsr,
    f: &Frontier,
    in_nbrs: Option<&InNbrs>,
    support: SupportMode,
    last_full_steps: u64,
    frac: f64,
    want_costs: bool,
) -> (bool, Option<Vec<u64>>) {
    match support {
        SupportMode::Full => (false, None),
        SupportMode::Incremental => (true, None),
        SupportMode::Auto => {
            let nbrs = in_nbrs.expect("auto mode builds the index");
            let (est, fc) = if want_costs {
                let fc = frontier_costs(z, f, nbrs);
                (fc.iter().sum(), Some(fc))
            } else {
                (frontier_costs_sum(z, f, nbrs), None)
            };
            let go = crossover(est, full_pass_estimate(z), last_full_steps, frac);
            (go, fc)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::support::compute_supports_seq;
    use crate::graph::builder::from_sorted_unique;
    use crate::graph::Csr;

    fn working(g: &Csr) -> (ZCsr, Vec<u32>) {
        let z = ZCsr::from_csr(g);
        let mut s = Vec::new();
        compute_supports_seq(&z, &mut s);
        (z, s)
    }

    /// Reference: prune with `prune()` (zeroing) and recompute fully.
    fn full_reference(z: &ZCsr, s: &[u32], k: u32) -> (ZCsr, Vec<u32>) {
        let mut z2 = z.clone();
        let mut s2 = s.to_vec();
        crate::algo::prune::prune(&mut z2, &mut s2, k);
        compute_supports_seq(&z2, &mut s2);
        (z2, s2)
    }

    #[test]
    fn fused_sweep_matches_separate_launches_minus_the_rereads() {
        let g = crate::testkit::graphs::peel_chain(16);
        let (z, s) = working(&g);
        let in_nbrs = InNbrs::build(&z);
        for k in [3u32, 4] {
            // separate launches (reference)
            let mut s_sep = s.clone();
            let f = mark_frontier(&z, &s_sep, k);
            let dec = decrement_frontier_seq(&z, &mut s_sep, &f, &in_nbrs);
            // fused sweep
            let mut s_fused = s.clone();
            let (f2, fused_steps) = fused_mark_decrement_seq(&z, &mut s_fused, k, &in_nbrs);
            assert_eq!(f2.tasks, f.tasks, "k={k}");
            assert_eq!(s_fused, s_sep, "k={k}");
            let separate = separate_mark_decrement_steps(&f, dec);
            assert_eq!(separate - fused_steps, f.len() as u64, "k={k}");
            if !f.is_empty() {
                assert!(fused_steps < separate, "k={k}");
            }
        }
    }

    /// Incremental: mark, decrement, compact-preserving.
    fn incremental_round(z: &ZCsr, s: &[u32], k: u32) -> (ZCsr, Vec<u32>, usize) {
        let mut z2 = z.clone();
        let mut s2 = s.to_vec();
        let in_nbrs = InNbrs::build(&z2);
        let f = mark_frontier(&z2, &s2, k);
        decrement_frontier_seq(&z2, &mut s2, &f, &in_nbrs);
        compact_preserving(&mut z2, &mut s2, &f.dying);
        (z2, s2, f.len())
    }

    #[test]
    fn support_mode_roundtrips_through_fromstr() {
        for m in [SupportMode::Full, SupportMode::Incremental, SupportMode::Auto] {
            let s = m.to_string();
            let back: SupportMode = s.parse().unwrap_or_else(|e| panic!("{s}: {e}"));
            assert_eq!(back, m, "{s}");
        }
        assert_eq!("inc".parse::<SupportMode>().unwrap(), SupportMode::Incremental);
        assert!("nope".parse::<SupportMode>().is_err());
        assert!(SupportMode::Auto.allows_incremental());
        assert!(!SupportMode::Full.allows_incremental());
    }

    #[test]
    fn in_nbrs_index_matches_columns() {
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let z = ZCsr::from_csr(&g);
        let idx = InNbrs::build(&z);
        assert_eq!(idx.of(0), &[] as &[Vid]);
        assert_eq!(idx.of(1), &[0]);
        assert_eq!(idx.of(2), &[0, 1]);
        assert_eq!(idx.of(3), &[0, 2]);
        assert_eq!(idx.len_of(2), 2);
    }

    #[test]
    fn mark_frontier_finds_sub_threshold_slots() {
        // diamond + pendant (3,4): pendant has support 0
        let g = from_sorted_unique(5, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3), (3, 4)]);
        let (z, s) = working(&g);
        let f = mark_frontier(&z, &s, 3); // threshold 1
        assert_eq!(f.len(), 1);
        let t = f.tasks[0];
        assert_eq!(t.row, 3);
        assert_eq!(z.col()[t.p as usize], 4);
        assert!(f.dying.get(t.p as usize));
        // pre-prune live counts include the dying edge
        assert_eq!(f.live[3], 2);
    }

    #[test]
    fn one_round_matches_full_recompute_on_fixtures() {
        let fixtures: Vec<Csr> = vec![
            from_sorted_unique(5, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3), (3, 4)]),
            crate::testkit::graphs::clique_with_tail(),
            crate::testkit::graphs::star_with_fringe(40),
            crate::gen::rmat::rmat(
                200,
                1500,
                crate::gen::rmat::RmatParams::autonomous_system(),
                &mut crate::util::Rng::new(7),
            ),
        ];
        for g in &fixtures {
            let (z, s) = working(g);
            for k in [3u32, 4, 5, 8] {
                let (z_full, s_full) = full_reference(&z, &s, k);
                let (z_inc, s_inc, _) = incremental_round(&z, &s, k);
                assert_eq!(z_inc, z_full, "k={k}");
                assert_eq!(s_inc, s_full, "k={k}");
            }
        }
    }

    #[test]
    fn multi_round_cascade_stays_exact() {
        // run the incremental rounds to convergence, checking the
        // maintained supports against a recompute every round
        let g = crate::gen::rmat::rmat(
            300,
            2200,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(21),
        );
        let (mut z, mut s) = working(&g);
        let in_nbrs = InNbrs::build(&z);
        for k in [4u32, 5] {
            let mut rounds = 0usize;
            loop {
                let f = mark_frontier(&z, &s, k);
                if f.is_empty() {
                    break;
                }
                decrement_frontier_seq(&z, &mut s, &f, &in_nbrs);
                compact_preserving(&mut z, &mut s, &f.dying);
                let mut want = Vec::new();
                compute_supports_seq(&z, &mut want);
                assert_eq!(s, want, "k={k} round={rounds}");
                rounds += 1;
                if z.live_edges() == 0 {
                    break;
                }
            }
        }
    }

    #[test]
    fn atomic_task_matches_seq_task() {
        let g = crate::gen::erdos_renyi::gnm(150, 900, &mut crate::util::Rng::new(9));
        let (z, s) = working(&g);
        let in_nbrs = InNbrs::build(&z);
        let f = mark_frontier(&z, &s, 4);
        let mut s_seq = s.clone();
        let steps_seq = decrement_frontier_seq(&z, &mut s_seq, &f, &in_nbrs);
        let s_at: Vec<AtomicU32> = s.iter().map(|&x| AtomicU32::new(x)).collect();
        let mut steps_at = 0u64;
        for &t in &f.tasks {
            steps_at += frontier_task_atomic(&z, &s_at, &f, &in_nbrs, t);
        }
        let s_at_plain: Vec<u32> = s_at.iter().map(|x| x.load(Ordering::Relaxed)).collect();
        assert_eq!(s_seq, s_at_plain);
        assert_eq!(steps_seq, steps_at);
    }

    #[test]
    fn frontier_costs_dominate_actual_steps() {
        let g = crate::gen::rmat::rmat(
            250,
            1800,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(13),
        );
        let (z, s) = working(&g);
        let in_nbrs = InNbrs::build(&z);
        for k in [4u32, 6] {
            let f = mark_frontier(&z, &s, k);
            let costs = frontier_costs(&z, &f, &in_nbrs);
            assert_eq!(costs.len(), f.len());
            let mut s2 = s.clone();
            let (_, per_task) = decrement_frontier_traced(&z, &mut s2, &f, &in_nbrs);
            for (i, (&est, &actual)) in costs.iter().zip(per_task.iter()).enumerate() {
                assert!(
                    est >= actual as u64,
                    "k={k} task {i}: estimate {est} below actual {actual}"
                );
            }
        }
    }

    #[test]
    fn compact_preserving_handles_tombstone_only_rows() {
        // row 0 dies entirely; surviving rows keep their supports
        let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
        let (mut z, mut s) = working(&g);
        let mut dying = BitSet::new(z.slots());
        let (start, _) = z.row_span(0);
        for p in start..start + 3 {
            dying.set(p);
        }
        let out = compact_preserving(&mut z, &mut s, &dying);
        assert_eq!(out.removed, 3);
        assert_eq!(out.remaining, 2);
        assert_eq!(z.row_live(0), &[] as &[u32]);
        assert!(crate::graph::validate::check_zcsr(&z).is_ok());
        // and a second compaction over the tombstone-only row is a no-op
        let dying = BitSet::new(z.slots());
        let out = compact_preserving(&mut z, &mut s, &dying);
        assert_eq!(out.removed, 0);
        assert_eq!(out.remaining, 2);
    }

    #[test]
    fn empty_frontier_is_a_noop() {
        let g = from_sorted_unique(3, &[(0, 1), (0, 2), (1, 2)]);
        let (z, s) = working(&g);
        let in_nbrs = InNbrs::build(&z);
        let f = mark_frontier(&z, &s, 3);
        assert!(f.is_empty());
        let mut s2 = s.clone();
        assert_eq!(decrement_frontier_seq(&z, &mut s2, &f, &in_nbrs), 0);
        assert_eq!(s2, s);
    }

    #[test]
    fn all_edges_die_in_one_pass() {
        // a path has zero support everywhere: the whole graph is the
        // frontier, every triangle enumeration finds nothing
        let g = crate::testkit::graphs::path(10);
        let (mut z, mut s) = working(&g);
        let in_nbrs = InNbrs::build(&z);
        let f = mark_frontier(&z, &s, 3);
        assert_eq!(f.len(), g.nnz());
        // triangle-free: no matches, so no decrement ever fires
        decrement_frontier_seq(&z, &mut s, &f, &in_nbrs);
        let out = compact_preserving(&mut z, &mut s, &f.dying);
        assert_eq!(out.remaining, 0);
        assert_eq!(z.live_edges(), 0);
        assert!(s.iter().all(|&x| x == 0));
    }

    #[test]
    fn crossover_prefers_small_frontiers() {
        assert!(crossover(10, 1000, 1000, DEFAULT_CROSSOVER_FRAC));
        assert!(!crossover(900, 1000, 1000, DEFAULT_CROSSOVER_FRAC));
        // the measured side tightens the proxy
        assert!(!crossover(300, 100_000, 400, DEFAULT_CROSSOVER_FRAC));
        // degenerate zero proxies never divide by zero
        assert!(!crossover(1, 0, 0, DEFAULT_CROSSOVER_FRAC));
    }

    /// Splice the maintained supports of `z_old` into the slot layout
    /// of the post-insertion form `z_new`, marking every slot with no
    /// old counterpart as inserted. Old rows must be subsets of new
    /// rows (insertion only grows rows).
    fn spliced(z_old: &ZCsr, s_old: &[u32], z_new: &ZCsr) -> (Vec<u32>, BitSet) {
        let mut s = vec![0u32; z_new.slots()];
        let mut inserted = BitSet::new(z_new.slots());
        for i in 0..z_new.n() {
            let (ns, _) = z_new.row_span(i);
            let (old_row, os) = if i < z_old.n() {
                (z_old.row_live(i), z_old.row_span(i).0)
            } else {
                (&[] as &[Vid], 0)
            };
            let mut oj = 0usize;
            for (j, &c) in z_new.row_live(i).iter().enumerate() {
                if oj < old_row.len() && old_row[oj] == c {
                    s[ns + j] = s_old[os + oj];
                    oj += 1;
                } else {
                    inserted.set(ns + j);
                }
            }
            assert_eq!(oj, old_row.len(), "old row {i} is not a subset of the new row");
        }
        (s, inserted)
    }

    /// Drop every `stride`-th edge of `g`, returning the shrunken graph
    /// and the dropped set (the insertion batch to replay).
    fn drop_every(g: &Csr, stride: usize) -> (Csr, Vec<(Vid, Vid)>) {
        let mut kept = Vec::new();
        let mut dropped = Vec::new();
        for (i, e) in g.edges().enumerate() {
            if i % stride == 0 {
                dropped.push(e);
            } else {
                kept.push(e);
            }
        }
        (from_sorted_unique(g.n(), &kept), dropped)
    }

    #[test]
    fn increment_matches_recompute_after_insertion() {
        let g = crate::gen::rmat::rmat(
            220,
            1600,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(41),
        );
        let (shrunk, _) = drop_every(&g, 3);
        let (z_old, s_old) = working(&shrunk);
        // post-insertion form: the full graph again
        let z_new = ZCsr::from_csr(&g);
        let (mut s, inserted) = spliced(&z_old, &s_old, &z_new);
        let f = frontier_from_marked(&z_new, &inserted);
        assert_eq!(f.len(), g.nnz() - shrunk.nnz());
        let in_nbrs = InNbrs::build(&z_new);
        let steps = increment_frontier_seq(&z_new, &mut s, &f, &in_nbrs);
        assert!(steps > 0);
        let (_, want) = working(&g);
        assert_eq!(s, want, "maintained supports diverged from recompute");
    }

    #[test]
    fn increment_atomic_matches_seq_with_exact_steps() {
        let g = crate::gen::erdos_renyi::gnm(180, 1100, &mut crate::util::Rng::new(19));
        let (shrunk, _) = drop_every(&g, 4);
        let (z_old, s_old) = working(&shrunk);
        let z_new = ZCsr::from_csr(&g);
        let (s0, inserted) = spliced(&z_old, &s_old, &z_new);
        let f = frontier_from_marked(&z_new, &inserted);
        let in_nbrs = InNbrs::build(&z_new);
        let mut s_seq = s0.clone();
        let steps_seq = increment_frontier_seq(&z_new, &mut s_seq, &f, &in_nbrs);
        let s_at: Vec<AtomicU32> = s0.iter().map(|&x| AtomicU32::new(x)).collect();
        let mut steps_at = 0u64;
        for &t in &f.tasks {
            steps_at += increment_task_atomic(&z_new, &s_at, &f, &in_nbrs, t);
        }
        let s_at_plain: Vec<u32> = s_at.iter().map(|x| x.load(Ordering::Relaxed)).collect();
        assert_eq!(s_seq, s_at_plain);
        assert_eq!(steps_seq, steps_at, "atomic and seq step counts must be identical");
    }

    #[test]
    fn zero_triangle_insertion_is_support_noop() {
        // re-inserting one path edge creates no triangles: the pass
        // runs (candidate scans count steps) but no support moves
        let g = crate::testkit::graphs::path(8);
        let mut kept: Vec<(Vid, Vid)> = g.edges().collect();
        kept.retain(|&(u, _)| u != 3);
        let shrunk = from_sorted_unique(g.n(), &kept);
        let (z_old, s_old) = working(&shrunk);
        let z_new = ZCsr::from_csr(&g);
        let (mut s, inserted) = spliced(&z_old, &s_old, &z_new);
        let f = frontier_from_marked(&z_new, &inserted);
        assert_eq!(f.len(), 1);
        let in_nbrs = InNbrs::build(&z_new);
        increment_frontier_seq(&z_new, &mut s, &f, &in_nbrs);
        assert!(s.iter().all(|&x| x == 0), "path supports must stay zero");
    }

    #[test]
    fn empty_marked_frontier_is_an_increment_noop() {
        let g = from_sorted_unique(3, &[(0, 1), (0, 2), (1, 2)]);
        let (z, s) = working(&g);
        let f = frontier_from_marked(&z, &BitSet::new(z.slots()));
        assert!(f.is_empty());
        assert_eq!(f.live, vec![2, 1, 0]);
        let in_nbrs = InNbrs::build(&z);
        let mut s2 = s.clone();
        assert_eq!(increment_frontier_seq(&z, &mut s2, &f, &in_nbrs), 0);
        assert_eq!(s2, s);
    }

    #[test]
    fn frontier_from_marked_matches_mark_frontier() {
        let g = crate::gen::community::communities(150, 900, 12, &mut crate::util::Rng::new(3));
        let (z, s) = working(&g);
        let scanned = mark_frontier(&z, &s, 4);
        let marked = frontier_from_marked(&z, &scanned.dying);
        assert_eq!(marked.tasks, scanned.tasks);
        assert_eq!(marked.dying, scanned.dying);
        assert_eq!(marked.live, scanned.live);
    }
}
