//! The K-truss driver: Algorithm 1's convergence loop over
//! `computeSupports` + `pruneEdges`, in both parallel granularities.

use super::prune::{prune, PruneOutcome};
use super::support::compute_supports_seq;
pub use super::support::Mode;
use crate::graph::{Csr, ZCsr};

/// Per-iteration record (consumed by the simulators and the bench
/// harness — each iteration corresponds to one kernel launch pair).
#[derive(Clone, Debug)]
pub struct IterationStat {
    /// Live edges at the start of the iteration.
    pub live_edges: usize,
    /// Edges pruned at the end of the iteration.
    pub removed: usize,
    /// Total merge-steps of the support pass (the real work measure).
    pub support_steps: u64,
}

/// Result of a K-truss computation.
#[derive(Clone, Debug)]
pub struct KtrussResult {
    /// The surviving k-truss subgraph (may be empty).
    pub truss: Csr,
    /// Number of support+prune iterations until convergence.
    pub iterations: usize,
    /// Per-iteration stats.
    pub stats: Vec<IterationStat>,
    /// Requested k.
    pub k: u32,
    /// Parallel granularity requested (identical results; recorded for
    /// provenance in bench output).
    pub mode: Mode,
}

impl KtrussResult {
    /// Edges in the truss.
    pub fn edges(&self) -> usize {
        self.truss.nnz()
    }

    /// Whether the truss came out empty.
    pub fn is_empty(&self) -> bool {
        self.truss.nnz() == 0
    }
}

/// Compute the k-truss of `g`. `mode` selects the task granularity used
/// by parallel/simulated executions; the sequential result is identical
/// for both (and is verified so by tests).
pub fn ktruss(g: &Csr, k: u32, mode: Mode) -> KtrussResult {
    let mut z = ZCsr::from_csr(g);
    let mut s: Vec<u32> = Vec::new();
    let (iterations, stats) = run_to_convergence(&mut z, &mut s, k);
    KtrussResult { truss: z.to_csr(), iterations, stats, k, mode }
}

/// In-place driver over an existing working copy; returns
/// (iterations, per-iteration stats). Used by [`ktruss`], by the
/// decomposition (which re-enters with increasing k), and by the
/// simulators (which replay the same loop through the cost tracer).
pub fn run_to_convergence(z: &mut ZCsr, s: &mut Vec<u32>, k: u32) -> (usize, Vec<IterationStat>) {
    let mut iterations = 0usize;
    let mut stats = Vec::new();
    loop {
        let live = z.live_edges();
        if live == 0 {
            break;
        }
        // Step 1: computeSupports (S ← AᵀA ∘ A, eager)
        let steps_before = sum_steps(z, s);
        // Step 2: pruneEdges (M ← S ≥ k-2; A ← A ∘ M)
        let out: PruneOutcome = prune(z, s, k);
        iterations += 1;
        stats.push(IterationStat { live_edges: live, removed: out.removed, support_steps: steps_before });
        if out.removed == 0 {
            break; // isUnchanged(M)
        }
    }
    (iterations, stats)
}

/// Run the support pass and return total merge-steps (work measure).
fn sum_steps(z: &ZCsr, s: &mut Vec<u32>) -> u64 {
    // compute_supports_seq clears + fills s
    compute_supports_seq(z, s);
    // steps are re-derived by a cheap second walk only when tracing is
    // requested; here we approximate with support-sum + live edges,
    // which the cost tracer replaces with exact counts.
    s.iter().map(|&x| x as u64).sum::<u64>() + z.live_edges() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_sorted_unique;

    #[test]
    fn k3_of_triangle_is_triangle() {
        let g = from_sorted_unique(3, &[(0, 1), (0, 2), (1, 2)]);
        let r = ktruss(&g, 3, Mode::Fine);
        assert_eq!(r.edges(), 3);
        assert!(r.iterations >= 1);
    }

    #[test]
    fn k3_strips_tree_parts() {
        // triangle with a path attached: path edges all die
        let g = from_sorted_unique(6, &[(0, 1), (0, 2), (1, 2), (2, 3), (3, 4), (4, 5)]);
        let r = ktruss(&g, 3, Mode::Coarse);
        assert_eq!(r.edges(), 3);
        assert_eq!(r.truss.row(0), &[1, 2]);
    }

    #[test]
    fn cascading_removal_takes_multiple_iterations() {
        // two triangles sharing edge (1,2); (2,3),(1,3) has support 1 but
        // removing pendant structures cascades:
        // graph: triangle {0,1,2}, plus triangle {1,2,3}, plus edge (3,4)
        // k=4 requires support>=2: edge (0,1),(0,2) support 1 -> die;
        // then {1,2,3} loses nothing... choose k=4: all edges die
        let g = from_sorted_unique(5, &[(0, 1), (0, 2), (1, 2), (1, 3), (2, 3), (3, 4)]);
        let r = ktruss(&g, 4, Mode::Fine);
        assert!(r.is_empty());
        assert!(r.iterations >= 2, "iterations {}", r.iterations);
    }

    #[test]
    fn k4_of_k4_survives() {
        let k4 = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        let r = ktruss(&k4, 4, Mode::Fine);
        assert_eq!(r.edges(), 6);
    }

    #[test]
    fn k5_of_k4_is_empty() {
        let k4 = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        let r = ktruss(&k4, 5, Mode::Coarse);
        assert!(r.is_empty());
    }

    #[test]
    fn modes_agree() {
        let g = crate::gen::rmat::rmat(
            400,
            3000,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(77),
        );
        for k in [3, 4, 5, 8] {
            let a = ktruss(&g, k, Mode::Coarse);
            let b = ktruss(&g, k, Mode::Fine);
            assert_eq!(a.truss, b.truss, "k={k}");
        }
    }

    #[test]
    fn stats_are_consistent() {
        let g = from_sorted_unique(6, &[(0, 1), (0, 2), (1, 2), (2, 3), (3, 4), (4, 5)]);
        let r = ktruss(&g, 3, Mode::Fine);
        assert_eq!(r.stats.len(), r.iterations);
        assert_eq!(r.stats[0].live_edges, 6);
        let total_removed: usize = r.stats.iter().map(|s| s.removed).sum();
        assert_eq!(total_removed, 6 - r.edges());
    }
}
