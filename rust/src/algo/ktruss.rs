//! The K-truss driver: Algorithm 1's convergence loop over
//! `computeSupports` + `pruneEdges`, in both parallel granularities and
//! both support-maintenance modes (full recompute vs the incremental
//! frontier update of [`super::incremental`]).

use super::incremental::{self, InNbrs, SupportMode};
use super::prune::prune;
use super::support::compute_supports_seq;
pub use super::support::Mode;
use crate::graph::{Csr, ZCsr};

/// Per-iteration record (consumed by the simulators and the bench
/// harness — each iteration corresponds to one kernel launch pair).
#[derive(Clone, Debug)]
pub struct IterationStat {
    /// Live edges at the start of the iteration.
    pub live_edges: usize,
    /// Edges pruned at the end of the iteration.
    pub removed: usize,
    /// Exact merge/search steps of the pass that produced this
    /// iteration's supports (the real work measure).
    pub support_steps: u64,
    /// Whether those supports came from incremental maintenance rather
    /// than a full recompute: a frontier update, or — for the first
    /// iteration of a warm-chained k-level (see
    /// [`run_to_convergence_mode`]) — supports inherited unchanged from
    /// the previous level with zero pass work (`support_steps == 0`).
    pub incremental: bool,
    /// Measured wall time of the pass that produced this iteration's
    /// supports, in milliseconds (0 for warm-inherited iterations).
    pub wall_ms: f64,
    /// Tasks offered to the worker pool for the pass (pre-split:
    /// rows for coarse, live edges for the finer granularities,
    /// frontier edges for incremental updates; 0 = sequential or
    /// warm-inherited).
    pub tasks: usize,
}

/// Result of a K-truss computation.
#[derive(Clone, Debug)]
pub struct KtrussResult {
    /// The surviving k-truss subgraph (may be empty).
    pub truss: Csr,
    /// Number of support+prune iterations until convergence.
    pub iterations: usize,
    /// Per-iteration stats.
    pub stats: Vec<IterationStat>,
    /// Requested k.
    pub k: u32,
    /// Parallel granularity requested (identical results; recorded for
    /// provenance in bench output).
    pub mode: Mode,
}

impl KtrussResult {
    /// Edges in the truss.
    pub fn edges(&self) -> usize {
        self.truss.nnz()
    }

    /// Whether the truss came out empty.
    pub fn is_empty(&self) -> bool {
        self.truss.nnz() == 0
    }

    /// Total support-pass steps across all iterations (the end-to-end
    /// work measure the incremental driver shrinks).
    pub fn total_support_steps(&self) -> u64 {
        self.stats.iter().map(|s| s.support_steps).sum()
    }
}

/// Compute the k-truss of `g` under the default [`SupportMode::Auto`]
/// driver. `mode` selects the task granularity used by
/// parallel/simulated executions; the sequential result is identical
/// for both (and is verified so by tests).
pub fn ktruss(g: &Csr, k: u32, mode: Mode) -> KtrussResult {
    ktruss_mode(g, k, mode, SupportMode::Auto)
}

/// [`ktruss`] with an explicit support-maintenance mode. All modes
/// produce the identical truss in the identical number of iterations;
/// they differ only in how much work each iteration's support pass
/// performs (recorded exactly in [`IterationStat::support_steps`]).
pub fn ktruss_mode(g: &Csr, k: u32, mode: Mode, support: SupportMode) -> KtrussResult {
    let mut z = ZCsr::from_csr(g);
    let mut s: Vec<u32> = Vec::new();
    let (iterations, stats) = run_to_convergence_mode(&mut z, &mut s, k, support, false);
    KtrussResult { truss: z.to_csr(), iterations, stats, k, mode }
}

/// In-place driver over an existing working copy; returns
/// (iterations, per-iteration stats). Used by [`ktruss`], by the
/// decomposition (which re-enters with increasing k), and by the
/// simulators (which replay the same loop through the cost tracer).
/// Runs the default [`SupportMode::Auto`] driver, cold.
pub fn run_to_convergence(z: &mut ZCsr, s: &mut Vec<u32>, k: u32) -> (usize, Vec<IterationStat>) {
    run_to_convergence_mode(z, s, k, SupportMode::Auto, false)
}

/// The convergence loop with explicit support maintenance.
///
/// Each round marks the sub-threshold frontier from the current
/// supports, records the iteration, and — when the frontier is
/// non-empty — brings the supports up to date for the shrunken graph
/// either by the incremental frontier update (decrement destroyed
/// triangles, compact rows *preserving* survivor supports) or by the
/// classic prune-and-recompute. [`SupportMode::Auto`] decides per round
/// via [`incremental::crossover`] on estimated frontier work vs a
/// full-pass proxy.
///
/// `warm` may be `true` only when `s` already holds the exact supports
/// of `z`'s current live edges (the state this function leaves behind
/// whenever it converges with live edges remaining) — then the initial
/// full pass is skipped, which is how [`super::kmax`] and
/// [`super::decompose`] chain k-levels incrementally. With
/// `warm == false` (or a mismatched `s`), the loop starts with a full
/// pass, exactly like the original driver.
///
/// Runs at the default crossover fraction; the planner-driven entry is
/// [`run_to_convergence_plan`].
pub fn run_to_convergence_mode(
    z: &mut ZCsr,
    s: &mut Vec<u32>,
    k: u32,
    support: SupportMode,
    warm: bool,
) -> (usize, Vec<IterationStat>) {
    run_to_convergence_plan(z, s, k, support, incremental::DEFAULT_CROSSOVER_FRAC, warm)
}

/// [`run_to_convergence_mode`] with an explicit auto-crossover fraction
/// — the knob an [`ExecutionPlan`](crate::plan::ExecutionPlan) carries.
/// The heuristic itself lives in [`incremental::decide_incremental`];
/// this driver only forwards the plan's fraction.
///
/// Live edges are maintained as a running counter fed by each round's
/// [`crate::algo::prune::PruneOutcome`] — the loop never rescans the
/// `O(slots)` column array — and the auto check runs through the
/// sum-only estimate variants (no per-round cost-vector allocation; the
/// sequential frontier pass has no binner to feed).
pub fn run_to_convergence_plan(
    z: &mut ZCsr,
    s: &mut Vec<u32>,
    k: u32,
    support: SupportMode,
    crossover: f64,
    warm: bool,
) -> (usize, Vec<IterationStat>) {
    let mut iterations = 0usize;
    let mut stats = Vec::new();
    // the one O(slots) scan; every later round updates the counter from
    // the prune/compaction outcome
    let mut live = z.live_edges();
    if live == 0 {
        return (iterations, stats);
    }
    let use_inc = support.allows_incremental();
    // one-time in-neighbor index; the graph only shrinks, so it stays a
    // valid superset for every later round (entries re-validated by
    // binary search in the kernel)
    let in_nbrs: Option<InNbrs> = if use_inc { Some(InNbrs::build(z)) } else { None };
    // steps and provenance of the pass that produced the *current* s
    let mut pass_steps: u64;
    let mut pass_incremental: bool;
    // wall time of that pass (span telemetry; 0 when no pass ran)
    let mut pass_wall_ms: f64;
    // measured steps of the most recent full pass (crossover proxy)
    let mut last_full_steps: u64;
    if use_inc && warm && s.len() == z.slots() {
        // supports inherited from a previous k-level: no pass ran
        pass_steps = 0;
        pass_incremental = true;
        pass_wall_ms = 0.0;
        last_full_steps = incremental::full_pass_estimate(z);
    } else {
        let t = crate::util::Timer::start();
        pass_steps = compute_supports_seq(z, s);
        pass_wall_ms = t.elapsed_ms();
        pass_incremental = false;
        last_full_steps = pass_steps;
    }
    loop {
        if live == 0 {
            break;
        }
        let f = incremental::mark_frontier(z, s, k);
        iterations += 1;
        stats.push(IterationStat {
            live_edges: live,
            removed: f.len(),
            support_steps: pass_steps,
            incremental: pass_incremental,
            wall_ms: pass_wall_ms,
            tasks: 0, // sequential driver: no pool tasks
        });
        if f.is_empty() {
            break; // isUnchanged(M): s stays valid for the survivors
        }
        let (go_incremental, _) = incremental::decide_incremental(
            z,
            &f,
            in_nbrs.as_ref(),
            support,
            last_full_steps,
            crossover,
            false,
        );
        if go_incremental {
            let nbrs = in_nbrs.as_ref().expect("incremental mode builds the index");
            let t = crate::util::Timer::start();
            pass_steps = incremental::decrement_frontier_seq(z, s, &f, nbrs);
            pass_wall_ms = t.elapsed_ms();
            pass_incremental = true;
            live = incremental::compact_preserving(z, s, &f.dying).remaining;
        } else {
            // classic path: compact (resetting supports), then recompute
            live = prune(z, s, k).remaining;
            if live == 0 {
                pass_steps = 0;
                pass_incremental = false;
                pass_wall_ms = 0.0;
            } else {
                let t = crate::util::Timer::start();
                pass_steps = compute_supports_seq(z, s);
                pass_wall_ms = t.elapsed_ms();
                pass_incremental = false;
                last_full_steps = pass_steps;
            }
        }
    }
    (iterations, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_sorted_unique;

    #[test]
    fn k3_of_triangle_is_triangle() {
        let g = from_sorted_unique(3, &[(0, 1), (0, 2), (1, 2)]);
        let r = ktruss(&g, 3, Mode::Fine);
        assert_eq!(r.edges(), 3);
        assert!(r.iterations >= 1);
    }

    #[test]
    fn k3_strips_tree_parts() {
        // triangle with a path attached: path edges all die
        let g = from_sorted_unique(6, &[(0, 1), (0, 2), (1, 2), (2, 3), (3, 4), (4, 5)]);
        let r = ktruss(&g, 3, Mode::Coarse);
        assert_eq!(r.edges(), 3);
        assert_eq!(r.truss.row(0), &[1, 2]);
    }

    #[test]
    fn cascading_removal_takes_multiple_iterations() {
        // two triangles sharing edge (1,2); (2,3),(1,3) has support 1 but
        // removing pendant structures cascades:
        // graph: triangle {0,1,2}, plus triangle {1,2,3}, plus edge (3,4)
        // k=4 requires support>=2: edge (0,1),(0,2) support 1 -> die;
        // then {1,2,3} loses nothing... choose k=4: all edges die
        let g = from_sorted_unique(5, &[(0, 1), (0, 2), (1, 2), (1, 3), (2, 3), (3, 4)]);
        let r = ktruss(&g, 4, Mode::Fine);
        assert!(r.is_empty());
        assert!(r.iterations >= 2, "iterations {}", r.iterations);
    }

    #[test]
    fn k4_of_k4_survives() {
        let k4 = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        let r = ktruss(&k4, 4, Mode::Fine);
        assert_eq!(r.edges(), 6);
    }

    #[test]
    fn k5_of_k4_is_empty() {
        let k4 = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        let r = ktruss(&k4, 5, Mode::Coarse);
        assert!(r.is_empty());
    }

    #[test]
    fn modes_agree() {
        let g = crate::gen::rmat::rmat(
            400,
            3000,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(77),
        );
        for k in [3, 4, 5, 8] {
            let a = ktruss(&g, k, Mode::Coarse);
            let b = ktruss(&g, k, Mode::Fine);
            assert_eq!(a.truss, b.truss, "k={k}");
        }
    }

    #[test]
    fn support_modes_agree_and_iterations_match() {
        let g = crate::gen::rmat::rmat(
            400,
            3000,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(78),
        );
        for k in [3, 4, 5, 8] {
            let full = ktruss_mode(&g, k, Mode::Fine, SupportMode::Full);
            let inc = ktruss_mode(&g, k, Mode::Fine, SupportMode::Incremental);
            let auto = ktruss_mode(&g, k, Mode::Fine, SupportMode::Auto);
            assert_eq!(full.truss, inc.truss, "k={k}");
            assert_eq!(full.truss, auto.truss, "k={k}");
            assert_eq!(full.iterations, inc.iterations, "k={k}");
            assert_eq!(full.iterations, auto.iterations, "k={k}");
            // provenance: the full driver never flags incremental, the
            // forced-incremental driver flags everything after pass 0
            assert!(full.stats.iter().all(|st| !st.incremental), "k={k}");
            assert!(
                inc.stats.iter().skip(1).all(|st| st.incremental),
                "k={k}"
            );
        }
    }

    #[test]
    fn incremental_cascade_does_less_work() {
        // multi-iteration cascade: the frontier rounds must be cheaper
        // than recomputing every round
        let g = crate::gen::rmat::rmat(
            600,
            4500,
            crate::gen::rmat::RmatParams::autonomous_system(),
            &mut crate::util::Rng::new(91),
        );
        for k in [4u32, 5] {
            let full = ktruss_mode(&g, k, Mode::Fine, SupportMode::Full);
            if full.iterations < 3 {
                continue; // no cascade at this k on this seed
            }
            let inc = ktruss_mode(&g, k, Mode::Fine, SupportMode::Incremental);
            assert!(
                inc.total_support_steps() < full.total_support_steps(),
                "k={k}: inc {} vs full {}",
                inc.total_support_steps(),
                full.total_support_steps()
            );
        }
    }

    #[test]
    fn warm_reentry_matches_cold() {
        // converge at k, then re-enter warm at k+1: identical outcome to
        // a cold run at k+1 on the pruned graph
        let g = crate::gen::community::communities(200, 1200, 15, &mut crate::util::Rng::new(5));
        let mut z = ZCsr::from_csr(&g);
        let mut s: Vec<u32> = Vec::new();
        run_to_convergence_mode(&mut z, &mut s, 3, SupportMode::Auto, false);
        let pruned = z.to_csr();
        let mut z_cold = ZCsr::from_csr(&pruned);
        let mut s_cold: Vec<u32> = Vec::new();
        let (it_cold, _) =
            run_to_convergence_mode(&mut z_cold, &mut s_cold, 4, SupportMode::Auto, false);
        let (it_warm, _) = run_to_convergence_mode(&mut z, &mut s, 4, SupportMode::Auto, true);
        assert_eq!(z.to_csr(), z_cold.to_csr());
        assert_eq!(it_warm, it_cold);
    }

    #[test]
    fn stats_are_consistent() {
        let g = from_sorted_unique(6, &[(0, 1), (0, 2), (1, 2), (2, 3), (3, 4), (4, 5)]);
        let r = ktruss(&g, 3, Mode::Fine);
        assert_eq!(r.stats.len(), r.iterations);
        assert_eq!(r.stats[0].live_edges, 6);
        let total_removed: usize = r.stats.iter().map(|s| s.removed).sum();
        assert_eq!(total_removed, 6 - r.edges());
    }

    #[test]
    fn exact_steps_match_trace_in_full_mode() {
        // satellite check: the driver's support_steps equal the exact
        // per-iteration traced totals, not the old sum(S)+live proxy
        let g = crate::gen::rmat::rmat(
            250,
            1800,
            crate::gen::rmat::RmatParams::social(),
            &mut crate::util::Rng::new(15),
        );
        let r = ktruss_mode(&g, 4, Mode::Fine, SupportMode::Full);
        let mut traced: Vec<u64> = Vec::new();
        crate::cost::replay::replay_ktruss(&g, 4, |o| traced.push(o.trace.total_steps));
        let got: Vec<u64> = r.stats.iter().map(|s| s.support_steps).collect();
        assert_eq!(got, traced);
    }
}
