//! Property tests for the work-aware scheduling subsystem
//! (`par::balance` + the new `Schedule` variants): schedule-independent
//! correctness of the support pass over every generator family, and
//! the scan binner's partition/balance invariants.

use ktruss::algo::support::{compute_supports_seq, Granularity, Mode};
use ktruss::gen::suite;
use ktruss::graph::ZCsr;
use ktruss::par::{
    balance, compute_supports_gran, compute_supports_par, Pool, Schedule, ALL_SCHEDULES,
};
use ktruss::testkit::graphs::arbitrary_graph;
use ktruss::testkit::{forall, Config};

/// The support array must be schedule-invariant: every schedule (old
/// and new), in both granularities, reproduces the sequential result
/// exactly, on arbitrary random graphs.
#[test]
fn prop_supports_schedule_invariant_on_arbitrary_graphs() {
    forall(Config::cases(15), arbitrary_graph, |g| {
        let z = ZCsr::from_csr(g);
        let mut want = Vec::new();
        compute_supports_seq(&z, &mut want);
        let pool = Pool::new(4);
        for mode in [Mode::Coarse, Mode::Fine] {
            for sched in ALL_SCHEDULES {
                let got = compute_supports_par(&z, &pool, mode, sched);
                if got != want {
                    return Err(format!("{mode} {sched:?}: parallel supports diverge"));
                }
            }
        }
        Ok(())
    });
}

/// Same invariant over every *suite generator family* (collab, p2p,
/// autonomous-system, social, co-purchase, road replicas).
#[test]
fn prop_supports_schedule_invariant_on_every_suite_family() {
    let representatives = [
        "ca-GrQc",          // Collab
        "p2p-Gnutella08",   // P2p
        "as20000102",       // AutonomousSystem
        "email-Enron",      // Social
        "amazon0302",       // Copurchase
        "roadNet-PA",       // Road
    ];
    let pool = Pool::new(4);
    for name in representatives {
        let spec = suite::by_name(name).unwrap();
        let g = suite::generate(spec, 0.03);
        let z = ZCsr::from_csr(&g);
        let mut want = Vec::new();
        compute_supports_seq(&z, &mut want);
        for mode in [Mode::Coarse, Mode::Fine] {
            for sched in ALL_SCHEDULES {
                let got = compute_supports_par(&z, &pool, mode, sched);
                assert_eq!(got, want, "{name} {mode} {sched:?}");
            }
        }
    }
}

/// The ultra-fine segment split must reproduce the sequential supports
/// exactly — per slot, hence also per row — for arbitrary segment
/// lengths, on arbitrary random graphs from every `testkit` family.
#[test]
fn prop_segmented_supports_match_row_level_supports() {
    forall(Config::cases(15), arbitrary_graph, |g| {
        let z = ZCsr::from_csr(g);
        let mut want = Vec::new();
        compute_supports_seq(&z, &mut want);
        let pool = Pool::new(4);
        for len in [1u32, 3, 32] {
            for sched in [Schedule::Static, Schedule::WorkAware, Schedule::Stealing] {
                let got =
                    compute_supports_gran(&z, &pool, Granularity::Segment { len }, sched);
                if got != want {
                    return Err(format!("len={len} {sched:?}: segmented supports diverge"));
                }
                // row-level aggregation agrees too (implied by the
                // per-slot equality, asserted for the paper's row sums)
                for i in 0..z.n() {
                    let (lo, hi) = z.row_span(i);
                    let a: u64 = got[lo..hi].iter().map(|&x| x as u64).sum();
                    let b: u64 = want[lo..hi].iter().map(|&x| x as u64).sum();
                    if a != b {
                        return Err(format!("len={len} {sched:?}: row {i} support sum"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Segment-split supports over every *suite generator family*
/// (collab, p2p, autonomous-system, social, co-purchase, road).
#[test]
fn prop_segmented_supports_on_every_suite_family() {
    let representatives = [
        "ca-GrQc",        // Collab
        "p2p-Gnutella08", // P2p
        "as20000102",     // AutonomousSystem
        "email-Enron",    // Social
        "amazon0302",     // Copurchase
        "roadNet-PA",     // Road
    ];
    let pool = Pool::new(4);
    for name in representatives {
        let spec = suite::by_name(name).unwrap();
        let g = suite::generate(spec, 0.03);
        let z = ZCsr::from_csr(&g);
        let mut want = Vec::new();
        compute_supports_seq(&z, &mut want);
        for len in [2u32, 64] {
            for sched in [Schedule::WorkAware, Schedule::Stealing] {
                let got = compute_supports_gran(&z, &pool, Granularity::Segment { len }, sched);
                assert_eq!(got, want, "{name} len={len} {sched:?}");
            }
        }
    }
}

/// The scan binner partitions `0..n` exactly once: contiguous,
/// in-order, first bin starts at 0, last bin ends at n.
#[test]
fn prop_scan_bins_partition_exactly_once() {
    forall(
        Config::cases(50),
        |rng| {
            let n = rng.range(0, 500);
            let bins = rng.range(1, 65);
            let costs: Vec<u64> = (0..n).map(|_| rng.below(1000)).collect();
            (costs, bins)
        },
        |(costs, bins)| {
            let b = balance::scan_bins(costs, *bins);
            if b.len() != *bins {
                return Err(format!("{} bins, wanted {bins}", b.len()));
            }
            let mut expect_lo = 0usize;
            for &(lo, hi) in &b {
                if lo != expect_lo {
                    return Err(format!("gap/overlap at {lo} (expected {expect_lo})"));
                }
                if hi < lo {
                    return Err(format!("inverted bin [{lo},{hi})"));
                }
                expect_lo = hi;
            }
            if expect_lo != costs.len() {
                return Err(format!("bins end at {expect_lo}, not {}", costs.len()));
            }
            Ok(())
        },
    );
}

/// Balance invariant: every bin's work is ≤ total/bins + max(cost)
/// (the boundary can overshoot by at most one task), which implies
/// max-bin-work ≤ 2× the mean bin work whenever no single task
/// exceeds the mean.
#[test]
fn prop_scan_bins_balanced() {
    forall(
        Config::cases(50),
        |rng| {
            let n = rng.range(1, 400);
            let bins = rng.range(1, 33);
            // mixed distribution, occasionally with a giant outlier
            let mut costs: Vec<u64> = (0..n).map(|_| 1 + rng.below(20)).collect();
            if rng.chance(0.5) {
                let i = rng.range(0, n);
                costs[i] = 5_000;
            }
            (costs, bins)
        },
        |(costs, bins)| {
            let b = balance::scan_bins(costs, *bins);
            let total: u64 = costs.iter().sum();
            let max_cost = *costs.iter().max().unwrap();
            let mean = total / *bins as u64;
            for &(lo, hi) in &b {
                let work: u64 = costs[lo..hi].iter().sum();
                if work > mean + max_cost + 1 {
                    return Err(format!(
                        "bin [{lo},{hi}) work {work} > mean {mean} + max {max_cost}"
                    ));
                }
                if max_cost <= mean && work > 2 * mean + 1 {
                    return Err(format!("bin work {work} > 2×mean {mean} with bounded tasks"));
                }
            }
            Ok(())
        },
    );
}

/// Cost estimates are true upper bounds on the exact traced work, for
/// both granularities, on arbitrary graphs.
#[test]
fn prop_cost_estimates_dominate_traces() {
    forall(Config::cases(20), arbitrary_graph, |g| {
        let z = ZCsr::from_csr(g);
        let mut s = Vec::new();
        let tr = ktruss::cost::trace::trace_supports(&z, &mut s);
        let fine = balance::estimate_costs(&z, Mode::Fine);
        for (p, (&est, &act)) in fine.iter().zip(tr.fine_steps.iter()).enumerate() {
            if est < act as u64 {
                return Err(format!("fine slot {p}: estimate {est} < actual {act}"));
            }
        }
        let coarse = balance::estimate_costs(&z, Mode::Coarse);
        for i in 0..z.n() {
            let act = tr.row_steps(z.row_ptr(), i);
            if coarse[i] < act {
                return Err(format!("coarse row {i}: estimate {} < actual {act}", coarse[i]));
            }
        }
        Ok(())
    });
}

/// Full k-truss through the pool agrees with the sequential driver for
/// the work-aware schedules on arbitrary graphs.
#[test]
fn prop_ktruss_par_workaware_matches_seq() {
    use ktruss::algo::ktruss::ktruss;
    use ktruss::par::ktruss_par;
    forall(Config::cases(10), arbitrary_graph, |g| {
        let pool = Pool::new(3);
        for k in [3u32, 5] {
            let want = ktruss(g, k, Mode::Fine);
            for sched in [Schedule::WorkAware, Schedule::Stealing] {
                for mode in [Mode::Coarse, Mode::Fine] {
                    let got = ktruss_par(g, k, &pool, mode, sched);
                    if got.truss != want.truss {
                        return Err(format!("k={k} {mode} {sched:?}: truss diverges"));
                    }
                    if got.iterations != want.iterations {
                        return Err(format!("k={k} {mode} {sched:?}: iteration count diverges"));
                    }
                }
            }
        }
        Ok(())
    });
}
