//! The reproduction's success criteria (DESIGN.md §5): the *shape* of
//! the paper's results must hold on the simulated testbeds — who wins,
//! by roughly what factor, and where the null effects are.

use ktruss::algo::support::{Granularity, Mode};
use ktruss::gen::suite;
use ktruss::par::Schedule;
use ktruss::sim::{gpu_schedule_grid, simulate_kmax, simulate_ktruss, table1_configs, SimConfig};

const SCALE: f64 = 0.1;

fn by<'a>(res: &'a [ktruss::sim::SimResult], label: &str) -> &'a ktruss::sim::SimResult {
    res.iter().find(|r| r.label.contains(label)).unwrap()
}

/// Fine must beat coarse on the hub-heavy families, on both devices
/// (paper Figs 3-4: speedup above unity almost everywhere).
#[test]
fn fine_beats_coarse_on_skewed_families() {
    for name in ["as20000102", "oregon1_010331", "soc-Epinions1", "email-Enron"] {
        let g = suite::load(suite::by_name(name).unwrap(), SCALE).unwrap();
        let res = simulate_ktruss(&g, 3, &table1_configs());
        let cpu = by(&res, "CPU-C").seconds / by(&res, "CPU-F").seconds;
        let gpu = by(&res, "GPU-C").seconds / by(&res, "GPU-F").seconds;
        assert!(cpu > 1.0, "{name}: CPU fine/coarse {cpu} <= 1");
        assert!(gpu > 1.0, "{name}: GPU fine/coarse {gpu} <= 1");
    }
}

/// The GPU's fine-grained gain must dwarf the CPU's on power-law
/// graphs (paper headline: 16.93x vs 1.48x at K=3).
#[test]
fn gpu_gain_exceeds_cpu_gain() {
    let mut gpu_gains = Vec::new();
    let mut cpu_gains = Vec::new();
    for name in ["as20000102", "oregon2_010331", "soc-Slashdot0811", "email-Enron"] {
        let g = suite::load(suite::by_name(name).unwrap(), SCALE).unwrap();
        let res = simulate_ktruss(&g, 3, &table1_configs());
        cpu_gains.push(by(&res, "CPU-C").seconds / by(&res, "CPU-F").seconds);
        gpu_gains.push(by(&res, "GPU-C").seconds / by(&res, "GPU-F").seconds);
    }
    let cpu = ktruss::util::stats::geomean(&cpu_gains).unwrap();
    let gpu = ktruss::util::stats::geomean(&gpu_gains).unwrap();
    assert!(
        gpu > 2.0 * cpu,
        "GPU geomean gain {gpu:.2} must clearly exceed CPU's {cpu:.2}"
    );
}

/// Road networks show near-parity between granularities (paper Table I:
/// roadNet rows ~1.0x, even slightly below on GPU) — the null effect.
#[test]
fn road_networks_near_parity() {
    let g = suite::load(suite::by_name("roadNet-PA").unwrap(), SCALE).unwrap();
    let res = simulate_ktruss(&g, 3, &table1_configs());
    let cpu = by(&res, "CPU-C").seconds / by(&res, "CPU-F").seconds;
    let gpu = by(&res, "GPU-C").seconds / by(&res, "GPU-F").seconds;
    assert!((0.5..2.0).contains(&cpu), "road CPU ratio {cpu}");
    assert!((0.5..2.0).contains(&gpu), "road GPU ratio {gpu}");
}

/// The GPU-coarse catastrophe on small AS graphs (paper: as20000102
/// GPU-C at 0.085 ME/s vs GPU-F 6.8 ME/s — 80x apart; oregon* similar).
#[test]
fn gpu_coarse_collapses_on_as_topologies() {
    let g = suite::load(suite::by_name("as20000102").unwrap(), 0.25).unwrap();
    let res = simulate_ktruss(&g, 3, &table1_configs());
    let ratio = by(&res, "GPU-C").seconds / by(&res, "GPU-F").seconds;
    assert!(ratio > 5.0, "AS-graph GPU collapse ratio {ratio} too mild");
}

/// Fig-2 shape: the fine/coarse CPU advantage grows (or at least does
/// not invert) as threads increase on a skewed graph — imbalance only
/// matters when there are workers to starve.
#[test]
fn thread_scaling_amplifies_fine_advantage() {
    let g = suite::load(suite::by_name("oregon2_010331").unwrap(), SCALE).unwrap();
    let mut configs = Vec::new();
    for &t in &[1usize, 8, 48] {
        configs.push(SimConfig::cpu(t, Mode::Coarse));
        configs.push(SimConfig::cpu(t, Mode::Fine));
    }
    let (_, res) = simulate_kmax(&g, &configs);
    let ratio_at = |i: usize| res[2 * i].seconds / res[2 * i + 1].seconds;
    let (r1, r48) = (ratio_at(0), ratio_at(2));
    assert!(
        r48 > r1 * 0.9,
        "fine advantage should not collapse with threads: 1t {r1:.2} vs 48t {r48:.2}"
    );
    // at 1 thread there is no imbalance to fix — ratio near 1
    assert!((0.7..1.6).contains(&r1), "1-thread ratio should be ~1, got {r1:.2}");
}

/// The satellite acceptance check, end to end through the replay
/// driver: on the star hot-row graph the work-aware GPU schedule's
/// predicted total is never worse than static's, at every granularity
/// (with fewer warps than schedulers they tie; work-aware must not
/// regress), and the segment granularity beats coarse outright.
#[test]
fn gpu_workaware_not_worse_than_static_on_star_hot_row() {
    let g = ktruss::testkit::graphs::star_with_fringe(2000);
    let res = simulate_ktruss(&g, 3, &gpu_schedule_grid(64));
    // grid layout: 3 granularities × [static, workaware, stealing]
    for gi in 0..3 {
        let stat = res[gi * 3].seconds;
        let wa = res[gi * 3 + 1].seconds;
        assert!(
            wa <= stat * 1.001,
            "{}: workaware {wa} vs static {stat}",
            res[gi * 3 + 1].label
        );
    }
    let coarse_static = res[0].seconds;
    let seg_static = res[6].seconds;
    assert!(
        seg_static < coarse_static,
        "segment {seg_static} must beat coarse {coarse_static} on the hot row"
    );
}

/// The paper-qualitative GPU schedule claim, on the workload built to
/// sit in the regime where a schedule (and only a schedule) helps:
/// clustered hot warps with one divergent lane each, far more warps
/// than schedulers, no mega-task for the serial tail to hide behind.
/// Work-aware and stealing must beat the static contiguous waves
/// *strictly* at fine granularity.
#[test]
fn gpu_schedules_beat_static_on_divergence_comb_fine() {
    let g = ktruss::testkit::graphs::hub_divergence_comb(600, 2400, 1500);
    let cfgs = vec![
        SimConfig::gpu_gran(Granularity::Fine, Schedule::Static),
        SimConfig::gpu_gran(Granularity::Fine, Schedule::WorkAware),
        SimConfig::gpu_gran(Granularity::Fine, Schedule::Stealing),
    ];
    let res = simulate_ktruss(&g, 3, &cfgs);
    let (stat, wa, steal) = (res[0].seconds, res[1].seconds, res[2].seconds);
    assert!(
        wa < 0.8 * stat,
        "workaware {wa} must clearly beat static {stat}"
    );
    assert!(
        steal < 0.8 * stat,
        "stealing {steal} must clearly beat static {stat}"
    );
}

/// On the skewed RMAT replica the work-aware/stealing schedules stay
/// inside the provable sandwich of the static makespan at every
/// granularity (how much they *win* depends on where the
/// bandwidth/tail bounds sit — reported, not asserted, by
/// `bench gpu-sched`), and the granularity ladder holds at every
/// schedule: fine and segment beat coarse on the hub-heavy graph.
#[test]
fn gpu_grid_shape_on_skewed_rmat() {
    let g = ktruss::gen::rmat::rmat(
        12_000,
        70_000,
        ktruss::gen::rmat::RmatParams::autonomous_system(),
        &mut ktruss::util::Rng::new(11),
    );
    let res = simulate_ktruss(&g, 3, &gpu_schedule_grid(64));
    for gi in 0..3 {
        let stat = res[gi * 3].seconds;
        for si in 1..3 {
            let r = &res[gi * 3 + si];
            assert!(
                r.seconds <= stat * 2.0 + 1e-9,
                "{}: {} vs static {}",
                r.label,
                r.seconds,
                stat
            );
        }
    }
    for si in 0..3 {
        let coarse = res[si].seconds;
        assert!(res[3 + si].seconds < coarse, "fine must beat coarse ({})", res[si].label);
        assert!(res[6 + si].seconds < coarse, "segment must beat coarse ({})", res[si].label);
    }
}

/// K=3 speedups exceed K=Kmax speedups on the CPU (paper: 1.48 vs 1.26
/// — pruning shrinks the graph and with it the exploitable imbalance).
#[test]
fn k3_speedup_geq_kmax_speedup_cpu() {
    let mut k3 = Vec::new();
    let mut km = Vec::new();
    let cfgs = vec![SimConfig::cpu(48, Mode::Coarse), SimConfig::cpu(48, Mode::Fine)];
    for name in ["oregon1_010331", "as-caida20071105", "soc-Epinions1"] {
        let g = suite::load(suite::by_name(name).unwrap(), SCALE).unwrap();
        let r3 = simulate_ktruss(&g, 3, &cfgs);
        k3.push(r3[0].seconds / r3[1].seconds);
        let (_, rk) = simulate_kmax(&g, &cfgs);
        km.push(rk[0].seconds / rk[1].seconds);
    }
    let g3 = ktruss::util::stats::geomean(&k3).unwrap();
    let gk = ktruss::util::stats::geomean(&km).unwrap();
    assert!(
        g3 > gk * 0.8,
        "K=3 geomean {g3:.2} should be >= Kmax geomean {gk:.2} (paper: 1.48 vs 1.26)"
    );
}
