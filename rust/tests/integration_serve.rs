//! Integration: the sharded serving executor under mixed-priority load
//! — strict priority ordering (no inversion), EDF deadline accounting,
//! multi-shard correctness, and the throughput workload smoke.

use ktruss::algo::support::Mode;
use ktruss::coordinator::{JobKind, JobOutput};
use ktruss::serve::{Executor, Priority, ServeConfig, SubmitOpts};
use ktruss::util::Rng;
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn one_shard_one_worker() -> ServeConfig {
    ServeConfig {
        shards: 1,
        workers_per_shard: 1,
        enable_dense: false,
        ..Default::default()
    }
}

/// A job heavy enough (hundreds of ms in debug builds) to keep the
/// single worker busy while later submissions pile up in the queue.
fn blocker_graph() -> Arc<ktruss::graph::Csr> {
    Arc::new(ktruss::gen::rmat::rmat(
        600,
        4000,
        ktruss::gen::rmat::RmatParams::social(),
        &mut Rng::new(11),
    ))
}

#[test]
fn high_priority_jobs_are_never_inverted_behind_low() {
    let ex = Arc::new(Executor::start(one_shard_one_worker()));
    // occupy the only worker so every later job must queue
    let blocker = ex.submit_with(
        blocker_graph(),
        JobKind::Decompose,
        SubmitOpts { priority: Priority::Normal, deadline: None, degrade_store: None },
    );
    std::thread::sleep(Duration::from_millis(30)); // let the blocker start
    // low-priority jobs enter the queue FIRST, high-priority after —
    // the queue must still serve every high before any low. The jobs
    // are sized to run for tens of ms each so that completion order as
    // observed by the waiter threads (recording after `wait()` returns)
    // cannot be scrambled by scheduler noise: a reordering would need a
    // woken waiter to stay descheduled for an entire job execution.
    let g = Arc::new(ktruss::gen::erdos_renyi::gnm(500, 2500, &mut Rng::new(12)));
    let order: Arc<Mutex<Vec<Priority>>> = Arc::new(Mutex::new(Vec::new()));
    let mut waiters = Vec::new();
    for priority in [
        Priority::Low,
        Priority::Low,
        Priority::Low,
        Priority::High,
        Priority::High,
        Priority::High,
    ] {
        let t = ex.submit_with(
            Arc::clone(&g),
            JobKind::Ktruss { k: 3, mode: Mode::Fine },
            SubmitOpts { priority, deadline: None, degrade_store: None },
        );
        let order = Arc::clone(&order);
        waiters.push(std::thread::spawn(move || {
            let r = t.wait();
            assert!(r.output.is_ok());
            order.lock().unwrap().push(priority);
        }));
    }
    for w in waiters {
        w.join().unwrap();
    }
    assert!(blocker.wait().output.is_ok());
    let order = order.lock().unwrap();
    assert_eq!(order.len(), 6);
    let last_high = order.iter().rposition(|&p| p == Priority::High).unwrap();
    let first_low = order.iter().position(|&p| p == Priority::Low).unwrap();
    assert!(
        last_high < first_low,
        "priority inversion: completion order {order:?}"
    );
    ex.shutdown();
}

#[test]
fn deadline_misses_are_counted_per_shard() {
    let ex = Executor::start(one_shard_one_worker());
    // a 1 ns soft deadline is already expired by the time the job
    // executes, in any build profile: must be counted as a miss
    let g = Arc::new(ktruss::gen::erdos_renyi::gnm(60, 150, &mut Rng::new(13)));
    let missed = ex.submit_with(
        Arc::clone(&g),
        JobKind::Triangles,
        SubmitOpts {
            priority: Priority::High,
            deadline: Some(Duration::from_nanos(1)),
            degrade_store: None,
        },
    );
    // and one with a generous deadline: must not miss
    let ok = ex.submit_with(
        g,
        JobKind::Triangles,
        SubmitOpts {
            priority: Priority::High,
            deadline: Some(Duration::from_secs(600)),
            degrade_store: None,
        },
    );
    assert!(missed.wait().output.is_ok(), "missed deadlines never cancel jobs");
    assert!(ok.wait().output.is_ok());
    assert_eq!(ex.metrics.deadline_misses(), 1);
    assert_eq!(
        ex.metrics.shards()[0].deadline_miss.load(std::sync::atomic::Ordering::Relaxed),
        1
    );
    assert!(ex.metrics.render().contains("deadline_miss=1"));
    ex.shutdown();
}

#[test]
fn sharded_executor_serves_concurrent_mixed_load_correctly() {
    let ex = Arc::new(Executor::start(ServeConfig {
        shards: 2,
        workers_per_shard: 1,
        enable_dense: false,
        ..Default::default()
    }));
    let mut handles = Vec::new();
    for t in 0..3u64 {
        let ex = Arc::clone(&ex);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t);
            for i in 0..6 {
                let n = rng.range(30, 150);
                let m = (2 * n).min(n * (n - 1) / 2);
                let g = Arc::new(ktruss::gen::erdos_renyi::gnm(n, m, &mut rng));
                let priority = match i % 3 {
                    0 => Priority::High,
                    1 => Priority::Normal,
                    _ => Priority::Low,
                };
                let want_triangles = ktruss::algo::triangle::count_triangles(&g);
                let ticket = ex.submit_with(
                    Arc::clone(&g),
                    JobKind::Triangles,
                    SubmitOpts {
                        priority,
                        deadline: Some(Duration::from_secs(600)),
                        degrade_store: None,
                    },
                );
                match ticket.wait().output.expect("job ok") {
                    JobOutput::Triangles { count } => assert_eq!(count, want_triangles),
                    other => panic!("{other:?}"),
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let (done, failed, _) = ex.metrics.summary();
    assert_eq!((done, failed), (18, 0));
    // work is attributed across the shards and nothing missed the
    // generous deadlines
    let per_shard: u64 = ex
        .metrics
        .shards()
        .iter()
        .map(|s| s.jobs.load(std::sync::atomic::Ordering::Relaxed))
        .sum();
    assert_eq!(per_shard, 18);
    assert_eq!(ex.metrics.deadline_misses(), 0);
    assert!(ex.metrics.quantile(0.5).is_some());
    ex.shutdown();
}

#[test]
fn facade_and_executor_share_one_request_path() {
    // the Coordinator facade must behave identically to a 1-shard
    // executor, including schedule override provenance
    use ktruss::coordinator::{Coordinator, ServiceConfig};
    use ktruss::par::Schedule;
    let c = Coordinator::start(ServiceConfig {
        enable_dense: false,
        pool_workers: 2,
        schedule: Some(Schedule::Stealing),
        ..Default::default()
    });
    let g = Arc::new(ktruss::gen::erdos_renyi::gnm(200, 900, &mut Rng::new(21)));
    let want = ktruss::algo::ktruss::ktruss(&g, 3, Mode::Fine).truss.nnz();
    let r = c.submit(g, JobKind::Ktruss { k: 3, mode: Mode::Fine }).wait();
    assert_eq!(r.schedule, Some(Schedule::Stealing));
    match r.output.unwrap() {
        JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, want),
        other => panic!("{other:?}"),
    }
    // priority submission through the facade's backing executor
    let g2 = Arc::new(ktruss::gen::erdos_renyi::gnm(80, 200, &mut Rng::new(22)));
    let t = c.executor().submit_with(
        g2,
        JobKind::Triangles,
        SubmitOpts { priority: Priority::High, deadline: None, degrade_store: None },
    );
    assert!(t.wait().output.is_ok());
    c.shutdown();
}

#[test]
fn shedding_and_degradation_reach_terminal_outcomes() {
    use ktruss::coordinator::JobOutcome;
    use ktruss::serve::GraphStore;
    let ex = Executor::start(ServeConfig {
        shards: 1,
        workers_per_shard: 1,
        enable_dense: false,
        shed: true,
        ..Default::default()
    });
    let g = Arc::new(ktruss::gen::erdos_renyi::gnm(200, 1000, &mut Rng::new(31)));
    let store = Arc::new(GraphStore::new(&g, 3));
    // a Low job whose zero deadline cannot be met degrades to the stale
    // epoch when a resident store for the same k is supplied...
    let degraded = ex
        .try_submit_with(
            Arc::clone(&g),
            JobKind::Ktruss { k: 3, mode: Mode::Fine },
            SubmitOpts {
                priority: Priority::Low,
                deadline: Some(Duration::ZERO),
                degrade_store: Some(Arc::clone(&store)),
            },
        )
        .unwrap()
        .wait();
    assert_eq!(degraded.outcome, JobOutcome::Degraded);
    assert!(degraded.output.is_ok());
    // ...and is shed outright without one
    let shed = ex
        .try_submit_with(
            g,
            JobKind::Ktruss { k: 3, mode: Mode::Fine },
            SubmitOpts {
                priority: Priority::Low,
                deadline: Some(Duration::ZERO),
                degrade_store: None,
            },
        )
        .unwrap()
        .wait();
    assert_eq!(shed.outcome, JobOutcome::Shed);
    assert!(shed.output.is_err());
    assert_eq!(ex.metrics.shed.load(std::sync::atomic::Ordering::Relaxed), 1);
    assert_eq!(ex.metrics.degraded.load(std::sync::atomic::Ordering::Relaxed), 1);
    // zero-execution outcomes still uphold the span steps invariant the
    // telemetry smoke enforces: total_steps == sum of pass steps
    for s in ex.obs.spans.snapshot() {
        let sum: u64 = s.passes.iter().map(|p| p.steps).sum();
        assert_eq!(s.total_steps, sum, "span {} ({})", s.id, s.outcome);
    }
    ex.shutdown();
}

#[test]
fn throughput_workload_smoke() {
    use ktruss::bench_harness::serve_bench;
    let cfg = serve_bench::ThroughputConfig {
        jobs: 12,
        arrival_us: 50,
        total_workers: 2,
        shard_counts: vec![1, 2],
        deadline_ms: 60_000, // generous: smoke asserts plumbing, not SLOs
        seed: 5,
    };
    let report = serve_bench::run(&cfg, |_| {}).unwrap();
    assert_eq!(report.runs.len(), 2);
    assert!(report.runs.iter().all(|r| r.throughput_jps > 0.0));
    assert!(report.render().contains("miss%"));
}
