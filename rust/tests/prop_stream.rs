//! Differential mutation oracle for the streaming maintenance layer
//! (`algo::stream`, `serve::store`): after **every** applied batch the
//! maintained supports and k-truss must be bit-identical to a
//! from-scratch recompute of the mutated graph — across random
//! insert/delete sequences over every generator family, for k ∈
//! {3, 4, 8}, for the sequential driver and every schedule ×
//! granularity (including Hybrid) of the parallel one, and through the
//! epoch-versioned [`GraphStore`].

use ktruss::algo::incremental::SupportMode;
use ktruss::algo::ktruss::{ktruss_mode, Mode};
use ktruss::algo::stream::{EdgeBatch, StreamState};
use ktruss::algo::support::{compute_supports_seq, Granularity};
use ktruss::graph::{Csr, Vid, ZCsr};
use ktruss::par::{Pool, ALL_SCHEDULES};
use ktruss::plan::ExecutionPlan;
use ktruss::serve::GraphStore;
use ktruss::testkit::graphs::{arbitrary_graph, churn_chain};
use ktruss::testkit::{forall, Config};
use ktruss::util::Rng;

const GRANS: [Granularity; 4] = [
    Granularity::Coarse,
    Granularity::Fine,
    Granularity::Segment { len: 8 },
    Granularity::Hybrid { len: 8 },
];

/// Draw a random batch against the current graph: deletes of present
/// edges, inserts of arbitrary pairs (some present, some self-loops —
/// normalization must sort the junk out), and occasional out-of-range
/// garbage.
fn random_batch(g: &Csr, rng: &mut Rng) -> EdgeBatch {
    let edges: Vec<(Vid, Vid)> = g.edges().collect();
    let mut batch = EdgeBatch::default();
    if !edges.is_empty() {
        for _ in 0..rng.below(4) {
            batch.delete.push(edges[rng.range(0, edges.len())]);
        }
    }
    let n = g.n() as u64;
    for _ in 0..rng.below(5) {
        // unoriented and unvalidated on purpose
        batch.insert.push((rng.below(n) as Vid, rng.below(n) as Vid));
    }
    if rng.below(3) == 0 {
        batch.insert.push((0, 0));
        batch.delete.push((n as Vid, 0));
    }
    batch
}

/// The differential oracle: maintained supports and truss must equal a
/// from-scratch derivation on the current graph, bit for bit.
fn check_against_scratch(st: &StreamState, ctx: &str) -> Result<(), String> {
    let z = ZCsr::from_csr(st.graph());
    let mut want = Vec::new();
    compute_supports_seq(&z, &mut want);
    if st.supports() != &want[..] {
        return Err(format!("{ctx}: maintained supports diverged from scratch"));
    }
    let scratch = ktruss_mode(st.graph(), st.k(), Mode::Fine, SupportMode::Full);
    if st.truss() != &scratch.truss {
        return Err(format!(
            "{ctx}: maintained truss ({} edges) diverged from scratch ({} edges)",
            st.truss().nnz(),
            scratch.truss.nnz()
        ));
    }
    Ok(())
}

/// Sequential oracle: random insert/delete sequences over every
/// generator family stay bit-identical to scratch after every batch.
#[test]
fn prop_maintained_state_matches_scratch_after_every_batch() {
    forall(Config::cases(20), arbitrary_graph, |g| {
        for k in [3u32, 4, 8] {
            let mut st = StreamState::new(g, k);
            let mut rng = Rng::new(0x57EA ^ (g.nnz() as u64) ^ ((k as u64) << 32));
            for b in 0..4 {
                let batch = random_batch(st.graph(), &mut rng);
                st.apply(&batch);
                check_against_scratch(&st, &format!("k={k} batch {b}"))?;
            }
        }
        Ok(())
    });
}

/// A batch of nothing but rejectable mutations (self-loops, present
/// inserts, absent/out-of-range deletes) must do zero work and perturb
/// nothing.
#[test]
fn prop_rejected_mutations_never_perturb_state() {
    forall(Config::cases(12), arbitrary_graph, |g| {
        let mut st = StreamState::new(g, 4);
        let before = st.clone();
        let n = g.n() as Vid;
        let mut junk = EdgeBatch {
            insert: vec![(0, 0), (n, n)],
            delete: vec![(n, 0), (n + 3, n)],
        };
        if let Some((u, v)) = g.edges().next() {
            junk.insert.push((v, u)); // present edge, reversed
        }
        let out = st.apply(&junk);
        if out.inserted != 0 || out.deleted != 0 || out.rejected != junk.len() {
            return Err(format!("junk batch was not fully rejected: {out:?}"));
        }
        if out.frontier_steps != 0 || out.recomputed {
            return Err(format!("junk batch did work: {out:?}"));
        }
        if st.graph() != before.graph() || st.truss() != before.truss() {
            return Err("junk batch perturbed the maintained state".into());
        }
        if st.supports() != before.supports() {
            return Err("junk batch perturbed the maintained supports".into());
        }
        Ok(())
    });
}

/// Parallel oracle: replaying the same script under every schedule ×
/// granularity (including Hybrid) reproduces the sequential trajectory
/// bit for bit — same graphs, same trusses, same outcomes, and the
/// same exact step counts.
#[test]
fn prop_par_replay_is_bit_identical_across_the_plan_grid() {
    let pool = Pool::new(4);
    forall(Config::cases(6), arbitrary_graph, |g| {
        for k in [3u32, 4, 8] {
            let st0 = StreamState::new(g, k);
            let mut seq = st0.clone();
            let mut rng = Rng::new(0xD1FF ^ (g.nnz() as u64) ^ ((k as u64) << 32));
            let mut script = Vec::new();
            let mut expect = Vec::new();
            for _ in 0..3 {
                let batch = random_batch(seq.graph(), &mut rng);
                let out = seq.apply(&batch);
                script.push(batch);
                expect.push((out, seq.graph().clone(), seq.truss().clone()));
            }
            for sched in ALL_SCHEDULES {
                for gran in GRANS {
                    let plan = ExecutionPlan::fixed(sched, gran, SupportMode::Incremental);
                    let mut st = st0.clone();
                    for (b, batch) in script.iter().enumerate() {
                        let out = st.apply_par(batch, &pool, &plan);
                        let (want_out, want_g, want_t) = &expect[b];
                        if out != *want_out {
                            return Err(format!(
                                "k={k} {plan} batch {b}: outcome diverged ({out:?} vs \
                                 {want_out:?})"
                            ));
                        }
                        if st.graph() != want_g || st.truss() != want_t {
                            return Err(format!("k={k} {plan} batch {b}: state diverged"));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// The deterministic churn fixture replays identically across the full
/// plan grid: every batch defeats the fast path, flips the truss by
/// exactly six edges, and ends bit-identical to the sequential replay.
#[test]
fn churn_chain_replays_identically_across_the_plan_grid() {
    let pool = Pool::new(3);
    let (g, script) = churn_chain(8, 6);
    let st0 = StreamState::new(&g, 4);
    let mut seq = st0.clone();
    let expect: Vec<_> = script
        .iter()
        .map(|b| {
            let out = seq.apply(b);
            (out, seq.truss().clone())
        })
        .collect();
    assert!(expect.iter().all(|(out, _)| out.recomputed), "churn must defeat the fast path");
    for sched in ALL_SCHEDULES {
        for gran in GRANS {
            let plan = ExecutionPlan::fixed(sched, gran, SupportMode::Incremental);
            let mut st = st0.clone();
            for (b, batch) in script.iter().enumerate() {
                let out = st.apply_par(batch, &pool, &plan);
                assert_eq!(out, expect[b].0, "{plan} batch {b}: outcome diverged");
                assert_eq!(st.truss(), &expect[b].1, "{plan} batch {b}: truss diverged");
            }
            check_against_scratch(&st, &format!("{plan} end state")).unwrap();
        }
    }
}

/// The epoch-versioned store stays differential under random mutations:
/// every published epoch's truss matches a scratch recompute of that
/// epoch's graph, epochs advance by one per batch, and the initially
/// pinned snapshot never moves.
#[test]
fn store_epochs_stay_differential_under_random_mutations() {
    let mut rng = Rng::new(77);
    let g = arbitrary_graph(&mut rng);
    let store = GraphStore::new(&g, 4);
    let epoch0 = store.pin();
    for b in 0..5u64 {
        let batch = random_batch(&store.pin().graph, &mut rng);
        let (snap, out) = store.apply(&batch);
        assert_eq!(snap.epoch, b + 1, "epochs advance by one per batch");
        let scratch = ktruss_mode(&snap.graph, 4, Mode::Fine, SupportMode::Full);
        assert_eq!(*snap.truss, scratch.truss, "epoch {}: truss diverged", snap.epoch);
        assert_eq!(out.truss_edges, scratch.truss.nnz(), "epoch {}", snap.epoch);
    }
    assert_eq!(store.epoch(), 5);
    assert_eq!(epoch0.epoch, 0);
    assert_eq!(*epoch0.graph, g, "the pinned epoch-0 snapshot must stay immutable");
}
