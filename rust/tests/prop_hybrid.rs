//! Property tests for the hybrid (bitmap + tail-segment) intersection
//! subsystem and the unified step-accounting contract:
//!
//! * every segment kernel returns **at most** its task's
//!   [`SegTask::estimated_steps`] (setup included — the estimate is a
//!   true upper bound after the step-accounting fix), and the estimate
//!   itself is clamped by both the segment and the tail side;
//! * every bitmap kernel returns **exactly** its task's
//!   [`BitmapTask::estimated_steps`] (uniform one-step probes);
//! * hybrid passes produce byte-identical supports — and hybrid truss
//!   runs byte-identical trusses — to the plain merge path, over the
//!   testkit families, the suite generator families, all schedules and
//!   arbitrary segment lengths.

use ktruss::algo::bitmap::{
    compute_supports_hybrid_seq, eager_update_bitmap_atomic, eager_update_bitmap_seq, hybrid_tasks,
    HybridTasks,
};
use ktruss::algo::incremental::mark_frontier;
use ktruss::algo::ktruss::ktruss;
use ktruss::algo::support::{
    compute_supports_seq, eager_update_segment_atomic, eager_update_segment_seq, segment_tasks,
    Granularity, Mode,
};
use ktruss::gen::suite;
use ktruss::graph::ZCsr;
use ktruss::par::{
    compute_supports_gran, compute_supports_hybrid_tasks, ktruss_par_gran, prune_par, Pool,
    Schedule, ALL_SCHEDULES,
};
use ktruss::testkit::graphs::arbitrary_graph;
use ktruss::testkit::{forall, Config};
use std::sync::atomic::{AtomicU32, Ordering};

/// One representative per suite generator family (same set the balance
/// property tests pin).
const SUITE_REPRESENTATIVES: [&str; 6] = [
    "ca-GrQc",        // Collab
    "p2p-Gnutella08", // P2p
    "as20000102",     // AutonomousSystem
    "email-Enron",    // Social
    "amazon0302",     // Copurchase
    "roadNet-PA",     // Road
];

#[test]
fn prop_segment_kernel_steps_bounded_by_estimate() {
    // the step-accounting contract of the satellite fix: the kernel
    // counts its window-locate setup, the estimate counts it too, and
    // the estimate clamps by BOTH the segment length and the tail
    // length — so executed ≤ estimated on every task, every family
    forall(Config::cases(12), arbitrary_graph, |g| {
        let z = ZCsr::from_csr(g);
        let col = z.col();
        for len in [1u32, 2, 5, 33] {
            let tasks = segment_tasks(&z, len);
            let mut s = vec![0u32; z.slots()];
            let s_atomic: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
            for t in &tasks {
                let est = t.estimated_steps();
                let seg_len = (t.hi - t.lo) as u64;
                if est != seg_len.min(t.tail_len()) + 1 {
                    return Err(format!("len={len} {t:?}: estimate clamp broken"));
                }
                let steps = eager_update_segment_seq(col, &mut s, t);
                if steps > est {
                    return Err(format!(
                        "len={len} {t:?}: executed {steps} > estimated {est}"
                    ));
                }
                if steps == 0 {
                    return Err(format!("len={len} {t:?}: setup step not counted"));
                }
                // the atomic kernel shares the probe core: identical count
                let atomic_steps = eager_update_segment_atomic(col, &s_atomic, t);
                if atomic_steps != steps {
                    return Err(format!(
                        "len={len} {t:?}: atomic {atomic_steps} != seq {steps}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bitmap_kernel_steps_exact() {
    // bitmap probes are uniform one-step word tests: the kernels must
    // return exactly the chunk length, never an approximation
    forall(Config::cases(12), arbitrary_graph, |g| {
        let z = ZCsr::from_csr(g);
        let col = z.col();
        for len in [1u32, 4, 32] {
            let ht = hybrid_tasks(&z, len);
            let mut s = vec![0u32; z.slots()];
            let s_atomic: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
            for t in &ht.probe {
                let kappa = col[t.p as usize] as usize;
                let bm = ht.index.row(kappa).expect("probe task against unencoded row");
                let est = t.estimated_steps();
                if eager_update_bitmap_seq(col, &mut s, bm, t) != est {
                    return Err(format!("len={len} {t:?}: seq steps not exact"));
                }
                if eager_update_bitmap_atomic(col, &s_atomic, bm, t) != est {
                    return Err(format!("len={len} {t:?}: atomic steps not exact"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hybrid_supports_match_merge_on_arbitrary_graphs() {
    forall(Config::cases(12), arbitrary_graph, |g| {
        let z = ZCsr::from_csr(g);
        let mut want = Vec::new();
        compute_supports_seq(&z, &mut want);
        let pool = Pool::new(4);
        for len in [1u32, 3, 32] {
            let mut seq = Vec::new();
            compute_supports_hybrid_seq(&z, len, &mut seq);
            if seq != want {
                return Err(format!("len={len}: sequential hybrid supports diverge"));
            }
            for sched in ALL_SCHEDULES {
                let got = compute_supports_gran(&z, &pool, Granularity::Hybrid { len }, sched);
                if got != want {
                    return Err(format!("len={len} {sched:?}: hybrid supports diverge"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hybrid_supports_on_every_suite_family() {
    let pool = Pool::new(4);
    for name in SUITE_REPRESENTATIVES {
        let spec = suite::by_name(name).unwrap();
        let g = suite::generate(spec, 0.03);
        let z = ZCsr::from_csr(&g);
        let mut want = Vec::new();
        compute_supports_seq(&z, &mut want);
        for len in [2u32, 64] {
            for sched in [Schedule::WorkAware, Schedule::Stealing] {
                let got = compute_supports_gran(&z, &pool, Granularity::Hybrid { len }, sched);
                assert_eq!(got, want, "{name} len={len} {sched:?}");
            }
        }
    }
}

#[test]
fn prop_hybrid_truss_matches_merge_on_every_suite_family() {
    // end-to-end: the representation choice may change only how each
    // intersection is computed, never a single support — so every k
    // level converges to the identical truss in the identical number of
    // iterations
    let pool = Pool::new(4);
    for name in SUITE_REPRESENTATIVES {
        let spec = suite::by_name(name).unwrap();
        let g = suite::generate(spec, 0.03);
        for k in [3u32, 4, 8] {
            let want = ktruss(&g, k, Mode::Fine);
            for (len, sched) in [(2u32, Schedule::Static), (64, Schedule::WorkAware)] {
                let got = ktruss_par_gran(&g, k, &pool, Granularity::Hybrid { len }, sched);
                assert_eq!(got.truss, want.truss, "{name} k={k} len={len} {sched:?}");
                assert_eq!(
                    got.iterations, want.iterations,
                    "{name} k={k} len={len} {sched:?}"
                );
            }
        }
    }
}

#[test]
fn prop_hybrid_refresh_matches_rebuild_across_convergence() {
    // the convergence drivers keep ONE HybridTasks alive across
    // iterations, invalidating only the rows the frontier touched
    // (prune/compaction is row-local, so untouched rows' encodings are
    // unchanged). This property pins the contract: after every prune,
    // the refreshed index must be indistinguishable from a from-scratch
    // rebuild — identical estimated steps, and bit-identical supports
    // from the executed pass
    forall(Config::cases(10), arbitrary_graph, |g| {
        let pool = Pool::new(4);
        for (k, len) in [(3u32, 2u32), (4, 32)] {
            let mut z = ZCsr::from_csr(g);
            let mut s = vec![0u32; z.slots()];
            let mut ht = hybrid_tasks(&z, len);
            let mut pending: Vec<u32> = Vec::new();
            let mut round = 0usize;
            loop {
                ht.refresh(&z, len, &pending);
                pending.clear();
                let fresh = hybrid_tasks(&z, len);
                let (est_r, est_f) = (ht.estimated_steps(), fresh.estimated_steps());
                if est_r != est_f {
                    return Err(format!(
                        "k={k} len={len} round={round}: refreshed cost vector \
                         ({} tasks, {} steps) != rebuilt ({} tasks, {} steps)",
                        est_r.len(),
                        est_r.iter().sum::<u64>(),
                        est_f.len(),
                        est_f.iter().sum::<u64>()
                    ));
                }
                let run = |t: &HybridTasks| -> (Vec<u32>, u64) {
                    let sa: Vec<AtomicU32> =
                        (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
                    let total =
                        compute_supports_hybrid_tasks(&z, &pool, t, Schedule::Stealing, &sa);
                    (sa.iter().map(|x| x.load(Ordering::Relaxed)).collect(), total)
                };
                let (got, refreshed_total) = run(&ht);
                let (want, rebuilt_total) = run(&fresh);
                if got != want {
                    return Err(format!(
                        "k={k} len={len} round={round}: refreshed supports diverge from rebuild"
                    ));
                }
                if refreshed_total != rebuilt_total {
                    return Err(format!(
                        "k={k} len={len} round={round}: step totals {refreshed_total} != {rebuilt_total}"
                    ));
                }
                // advance one convergence round exactly like the
                // drivers' full-pass branch: mark, collect the stale
                // rows, prune
                s.copy_from_slice(&got);
                let f = mark_frontier(&z, &s, k);
                if f.is_empty() {
                    break;
                }
                let mut last = u32::MAX;
                for t in &f.tasks {
                    if t.row != last {
                        pending.push(t.row);
                        last = t.row;
                    }
                }
                if prune_par(&mut z, &mut s, k, &pool, Schedule::Static).remaining == 0 {
                    break;
                }
                round += 1;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hybrid_pass_step_totals_are_schedule_invariant() {
    // the pass's executed-step total is a property of the task list,
    // not of who ran which task: every schedule reports the sequential
    // hybrid total exactly
    forall(Config::cases(8), arbitrary_graph, |g| {
        let z = ZCsr::from_csr(g);
        let pool = Pool::new(4);
        for len in [2u32, 16] {
            let mut s_seq = Vec::new();
            let want = compute_supports_hybrid_seq(&z, len, &mut s_seq);
            for sched in ALL_SCHEDULES {
                let s: Vec<AtomicU32> = (0..z.slots()).map(|_| AtomicU32::new(0)).collect();
                let total =
                    ktruss::par::compute_supports_hybrid(&z, &pool, len, sched, &s);
                if total != want {
                    return Err(format!("len={len} {sched:?}: total {total} != {want}"));
                }
                let got: Vec<u32> = s.iter().map(|x| x.load(Ordering::Relaxed)).collect();
                if got != s_seq {
                    return Err(format!("len={len} {sched:?}: supports diverge"));
                }
            }
        }
        Ok(())
    });
}
