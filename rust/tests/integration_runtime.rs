//! Integration: the AOT bridge end-to-end — HLO text artifacts produced
//! by `make artifacts` (jax + Pallas, interpret-mode) loaded and
//! executed through the PJRT CPU client, validated against the sparse
//! rust path. This is the three-layer composition test.
//!
//! In the offline build the PJRT bridge is a stub (see
//! `runtime/client.rs`), so each test probes one real execution first
//! and skips — loudly — when the runtime cannot actually run
//! artifacts. The suite regains its teeth automatically the moment a
//! real bridge is linked in.

use ktruss::algo::ktruss::{ktruss, Mode};
use ktruss::algo::triangle;
use ktruss::graph::builder::from_sorted_unique;
use ktruss::graph::Csr;
use ktruss::runtime::DenseEngine;
use ktruss::util::Rng;

/// A dense engine that has proven it can execute, or `None` (skip).
/// Set `KTRUSS_REQUIRE_DENSE=1` to turn the skip into a hard failure —
/// use that in environments where artifacts and a real PJRT bridge are
/// expected, so a dense regression cannot hide behind a green suite.
fn engine() -> Option<DenseEngine> {
    let skip = |e: anyhow::Error| {
        if std::env::var_os("KTRUSS_REQUIRE_DENSE").is_some() {
            panic!("KTRUSS_REQUIRE_DENSE set but dense engine unavailable: {e:#}");
        }
        eprintln!("SKIP dense runtime tests: {e:#}");
        None
    };
    let eng = match DenseEngine::new() {
        Ok(e) => e,
        Err(e) => return skip(e),
    };
    let probe = from_sorted_unique(3, &[(0, 1), (0, 2), (1, 2)]);
    match eng.supports(&probe) {
        Ok(_) => Some(eng),
        Err(e) => skip(e),
    }
}

fn random_graph(n: usize, m: usize, seed: u64) -> Csr {
    ktruss::gen::rmat::rmat(n, m, ktruss::gen::rmat::RmatParams::social(), &mut Rng::new(seed))
}

#[test]
fn dense_supports_match_sparse_on_diamond() {
    let Some(eng) = engine() else { return };
    let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
    let sup = eng.supports(&g).expect("dense supports");
    assert_eq!(sup, vec![1, 2, 1, 1, 1]);
}

#[test]
fn dense_supports_match_naive_on_random_graphs() {
    let Some(eng) = engine() else { return };
    for seed in [1u64, 2, 3] {
        let g = random_graph(120, 800, seed);
        let dense = eng.supports(&g).expect("dense supports");
        let naive = triangle::edge_supports_naive(&g);
        assert_eq!(dense, naive, "seed={seed}");
    }
}

#[test]
fn dense_ktruss_matches_sparse_across_k() {
    let Some(eng) = engine() else { return };
    let g = random_graph(100, 600, 11);
    for k in [3u32, 4, 5, 7] {
        let (dense_truss, iters) = eng.ktruss(&g, k).expect("dense ktruss");
        let sparse = ktruss(&g, k, Mode::Fine);
        assert_eq!(dense_truss, sparse.truss, "k={k}");
        assert!(iters >= 1);
    }
}

#[test]
fn dense_ktruss_on_clique_with_tail() {
    let Some(eng) = engine() else { return };
    let mut edges = Vec::new();
    for u in 0..6u32 {
        for v in (u + 1)..6 {
            edges.push((u, v));
        }
    }
    edges.extend([(5, 6), (6, 7), (7, 8)]);
    let g = from_sorted_unique(9, &edges);
    let (truss, _) = eng.ktruss(&g, 6).unwrap();
    assert_eq!(truss.nnz(), 15); // K6 survives, tail dies
}

#[test]
fn dense_engine_rejects_oversized_graph() {
    let Some(eng) = engine() else { return };
    let big = ktruss::gen::erdos_renyi::gnm(eng.max_n() + 1, 500, &mut Rng::new(5));
    assert!(eng.supports(&big).is_err());
    assert!(eng.ktruss(&big, 3).is_err());
}

#[test]
fn dense_picks_block_for_mid_size_graph() {
    // between 128 and 256 -> must use the 256 block
    let Some(eng) = engine() else { return };
    if eng.max_n() < 256 {
        return;
    }
    let g = random_graph(200, 1200, 21);
    let dense = eng.supports(&g).expect("dense supports");
    let naive = triangle::edge_supports_naive(&g);
    assert_eq!(dense, naive);
}

#[test]
fn coordinator_routes_small_jobs_to_dense() {
    use ktruss::coordinator::{Coordinator, JobKind, JobOutput, ServiceConfig};
    use std::sync::Arc;
    if engine().is_none() {
        return;
    }
    let c = Coordinator::start(ServiceConfig { enable_dense: true, ..Default::default() });
    let g = Arc::new(random_graph(90, 500, 31));
    let sparse_want = ktruss(&g, 3, Mode::Fine);
    let t = c.submit(Arc::clone(&g), JobKind::Ktruss { k: 3, mode: Mode::Fine });
    let r = t.wait();
    assert_eq!(r.engine, ktruss::coordinator::Engine::DenseXla, "expected dense routing");
    match r.output.unwrap() {
        JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, sparse_want.truss.nnz()),
        other => panic!("{other:?}"),
    }
    c.shutdown();
}

/// The offline stub must degrade *gracefully*: a dense-routed job whose
/// runtime cannot execute falls back to the sparse pool and still
/// returns the correct truss. This test runs in every build.
#[test]
fn dense_failure_falls_back_to_sparse() {
    use ktruss::coordinator::{Engine, JobKind, JobRequest};
    use ktruss::coordinator::worker::run_inline;
    use std::sync::Arc;
    let g = Arc::new(from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]));
    let req = JobRequest { id: 1, graph: g, kind: JobKind::Ktruss { k: 3, mode: Mode::Fine } };
    let r = run_inline(&req, Engine::DenseXla);
    assert_eq!(r.engine, Engine::SparseCpu);
    match r.output.unwrap() {
        ktruss::coordinator::JobOutput::Ktruss { truss_edges, .. } => assert_eq!(truss_edges, 5),
        other => panic!("{other:?}"),
    }
}
