//! Plan-invariance property tests: every candidate [`ExecutionPlan`]
//! of the planner's grid — schedule × granularity × support mode, plus
//! crossover variations — must produce the *identical* truss on every
//! generator family. The plan decides only how the work is cut,
//! scheduled and maintained, never what is computed; this suite is the
//! license that lets the planner switch plans freely.

use ktruss::algo::incremental::SupportMode;
use ktruss::algo::ktruss::ktruss_mode;
use ktruss::algo::support::{Granularity, Mode};
use ktruss::graph::Csr;
use ktruss::par::{ktruss_par_plan, Pool, Schedule};
use ktruss::plan::{ExecutionPlan, PlanSpec, Planner};
use ktruss::util::Rng;

/// The candidate grid the planner enumerates (Dynamic is exercised via
/// the pool's shared code path; the three schedules here cover the
/// static, scan-binned and stealing executions).
fn plan_grid() -> Vec<ExecutionPlan> {
    let mut out = Vec::new();
    for sched in [Schedule::Static, Schedule::WorkAware, Schedule::Stealing] {
        for gran in [
            Granularity::Coarse,
            Granularity::Fine,
            Granularity::Segment { len: 8 },
            Granularity::Hybrid { len: 8 },
        ] {
            for support in [SupportMode::Full, SupportMode::Incremental, SupportMode::Auto] {
                out.push(ExecutionPlan::fixed(sched, gran, support));
            }
        }
    }
    out
}

/// One graph per generator family (plus the adversarial fixtures the
/// planner's shape tests use).
fn families() -> Vec<(String, Csr)> {
    let mut rng = Rng::new(0x91AD);
    vec![
        (
            "gnm".to_string(),
            ktruss::gen::erdos_renyi::gnm(180, 1100, &mut rng),
        ),
        (
            "rmat-social".to_string(),
            ktruss::gen::rmat::rmat(200, 1400, ktruss::gen::rmat::RmatParams::social(), &mut rng),
        ),
        (
            "rmat-as".to_string(),
            ktruss::gen::rmat::rmat(
                220,
                1500,
                ktruss::gen::rmat::RmatParams::autonomous_system(),
                &mut rng,
            ),
        ),
        (
            "communities".to_string(),
            ktruss::gen::community::communities(160, 1000, 12, &mut rng),
        ),
        (
            "star-fringe".to_string(),
            ktruss::testkit::graphs::star_with_fringe(80),
        ),
        ("peel-chain".to_string(), ktruss::testkit::graphs::peel_chain(16)),
    ]
}

#[test]
fn every_candidate_plan_yields_the_identical_truss() {
    let pool = Pool::new(4);
    let grid = plan_grid();
    for (name, g) in families() {
        for k in [3u32, 4, 8] {
            let want = ktruss_mode(&g, k, Mode::Fine, SupportMode::Full);
            for plan in &grid {
                let got = ktruss_par_plan(&g, k, &pool, plan);
                assert_eq!(got.truss, want.truss, "{name} k={k} plan={plan}");
                assert_eq!(
                    got.iterations, want.iterations,
                    "{name} k={k} plan={plan}"
                );
            }
        }
    }
}

#[test]
fn crossover_fraction_never_changes_the_result() {
    // the crossover steers *when* the frontier update runs, never what
    // it computes: extreme fractions must agree exactly
    let pool = Pool::new(3);
    let g = ktruss::testkit::graphs::peel_chain(24);
    for k in [3u32, 4] {
        let want = ktruss_mode(&g, k, Mode::Fine, SupportMode::Full);
        for crossover in [0.05, 0.5, 0.95] {
            let plan = ExecutionPlan {
                schedule: Schedule::WorkAware,
                granularity: Granularity::Fine,
                support: SupportMode::Auto,
                crossover,
                device: ktruss::plan::PlanDevice::Cpu,
            };
            let got = ktruss_par_plan(&g, k, &pool, &plan);
            assert_eq!(got.truss, want.truss, "k={k} crossover={crossover}");
            assert_eq!(got.iterations, want.iterations, "k={k} crossover={crossover}");
        }
    }
}

#[test]
fn planner_chosen_plans_are_correct_on_every_family() {
    // whatever the planner picks for a family, executing it matches the
    // sequential reference
    let pool = Pool::new(4);
    let planner = Planner::new(4);
    for (name, g) in families() {
        for k in [3u32, 4] {
            let plan = planner.choose(&g, k);
            let got = ktruss_par_plan(&g, k, &pool, &plan);
            let want = ktruss_mode(&g, k, Mode::Fine, SupportMode::Full);
            assert_eq!(got.truss, want.truss, "{name} k={k} plan={plan}");
        }
    }
}

#[test]
fn planner_shape_matches_the_paper_story() {
    // the satellite acceptance shapes, through the public API: fine,
    // segment or hybrid granularity on the hub fixtures, coarse on a
    // flat grid
    let planner = Planner::new(48);
    for (name, g) in [
        (
            "hub-comb",
            ktruss::testkit::graphs::hub_divergence_comb(64, 256, 800),
        ),
        ("star-fringe", ktruss::testkit::graphs::star_with_fringe(1200)),
    ] {
        let plan = planner.choose(&g, 3);
        assert!(
            matches!(
                plan.granularity,
                Granularity::Fine | Granularity::Segment { .. } | Granularity::Hybrid { .. }
            ),
            "{name}: {plan}"
        );
    }
    // pinned to merge-segment granularity the comb's clustered hot
    // region still demands a cost-aware schedule (the free grid may
    // instead pick hybrid, whose uniform probe chunks flatten the
    // imbalance at the representation level)
    let comb = ktruss::testkit::graphs::hub_divergence_comb(64, 256, 800);
    let seg: PlanSpec = "auto/segment/any".parse().unwrap();
    let plan = planner.clone().with_spec(seg).choose(&comb, 3);
    assert_ne!(plan.schedule, Schedule::Static, "comb: {plan}");
    let mut rng = Rng::new(6);
    let flat = ktruss::gen::grid::road(3000, 5800, 0.05, &mut rng);
    let plan = planner.choose(&flat, 3);
    assert_eq!(plan.granularity, Granularity::Coarse, "flat grid: {plan}");
}
