//! Integration: coordinator service under concurrent load — many
//! submitters, mixed job kinds and graph sizes, engine routing, and
//! metrics accounting.

use ktruss::algo::support::Mode;
use ktruss::coordinator::{Coordinator, JobKind, JobOutput, ServiceConfig};
use ktruss::util::Rng;
use std::sync::Arc;

fn service(pool: usize) -> Coordinator {
    Coordinator::start(ServiceConfig {
        pool_workers: pool,
        enable_dense: false, // keep this test independent of artifacts
        ..Default::default()
    })
}

#[test]
fn concurrent_submitters_all_jobs_complete_correctly() {
    let c = Arc::new(service(2));
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let c = Arc::clone(&c);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t);
            for i in 0..8 {
                let n = rng.range(30, 200);
                let m = (2 * n).min(n * (n - 1) / 2);
                let g = Arc::new(ktruss::gen::erdos_renyi::gnm(n, m, &mut rng));
                let want_triangles = ktruss::algo::triangle::count_triangles(&g);
                let kind = if i % 2 == 0 {
                    JobKind::Triangles
                } else {
                    JobKind::Ktruss { k: 3, mode: Mode::Fine }
                };
                let ticket = c.submit(Arc::clone(&g), kind);
                let r = ticket.wait();
                match r.output.expect("job ok") {
                    JobOutput::Triangles { count } => assert_eq!(count, want_triangles),
                    JobOutput::Ktruss { truss_edges, .. } => {
                        let want = ktruss::algo::ktruss::ktruss(&g, 3, Mode::Fine).truss.nnz();
                        assert_eq!(truss_edges, want);
                    }
                    other => panic!("{other:?}"),
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let (done, failed, mean_ms) = c.metrics.summary();
    assert_eq!(done, 32);
    assert_eq!(failed, 0);
    assert!(mean_ms >= 0.0);
    c.shutdown();
}

#[test]
fn mixed_job_kinds_roundtrip() {
    let c = service(2);
    let g = Arc::new(ktruss::testkit::graphs::clique_with_tail());
    let kt = c.submit(Arc::clone(&g), JobKind::Ktruss { k: 5, mode: Mode::Coarse }).wait();
    match kt.output.unwrap() {
        JobOutput::Ktruss { truss_edges, edges, .. } => {
            assert_eq!(truss_edges, 10); // K5 survives
            assert_eq!(edges.len(), 10);
        }
        other => panic!("{other:?}"),
    }
    let km = c.submit(Arc::clone(&g), JobKind::Kmax).wait();
    match km.output.unwrap() {
        JobOutput::Kmax { kmax, truss_edges } => {
            assert_eq!(kmax, 5);
            assert_eq!(truss_edges, 10);
        }
        other => panic!("{other:?}"),
    }
    let d = c.submit(Arc::clone(&g), JobKind::Decompose).wait();
    match d.output.unwrap() {
        JobOutput::Decompose { kmax, histogram } => {
            assert_eq!(kmax, 5);
            let total: usize = histogram.iter().map(|&(_, n)| n).sum();
            assert_eq!(total, g.nnz());
        }
        other => panic!("{other:?}"),
    }
    c.shutdown();
}

#[test]
fn tickets_can_be_polled() {
    let c = service(1);
    let g = Arc::new(ktruss::gen::erdos_renyi::gnm(500, 2000, &mut Rng::new(9)));
    let ticket = c.submit(g, JobKind::Kmax);
    // poll until done (bounded)
    let mut result = None;
    for _ in 0..10_000 {
        if let Some(r) = ticket.try_get() {
            result = Some(r);
            break;
        }
        std::thread::sleep(std::time::Duration::from_micros(200));
    }
    assert!(result.expect("polled result").output.is_ok());
    c.shutdown();
}

#[test]
fn throughput_batching_many_small_jobs() {
    let c = service(2);
    let mut rng = Rng::new(77);
    let tickets: Vec<_> = (0..64)
        .map(|_| {
            let n = rng.range(20, 60);
            let g = Arc::new(ktruss::gen::erdos_renyi::gnm(n, n, &mut rng));
            c.submit(g, JobKind::Triangles)
        })
        .collect();
    for t in tickets {
        assert!(t.wait().output.is_ok());
    }
    let (done, failed, _) = c.metrics.summary();
    assert_eq!((done, failed), (64, 0));
    c.shutdown();
}
