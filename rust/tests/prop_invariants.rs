//! Property-based invariant tests over random graphs (testkit::forall
//! stands in for proptest, which is unavailable offline).

use ktruss::algo::ktruss::ktruss;
use ktruss::algo::support::{compute_supports_seq, Mode};
use ktruss::algo::{decompose, kmax, reference, triangle};
use ktruss::graph::{validate, Csr, ZCsr};
use ktruss::testkit::graphs::arbitrary_graph;
use ktruss::testkit::{forall, Config};
use std::collections::HashSet;

/// Every edge of the k-truss must close ≥ k-2 triangles *within the
/// truss* — the defining property, checked on the output subgraph.
#[test]
fn prop_truss_edges_have_min_support() {
    forall(Config::cases(40), arbitrary_graph, |g| {
        for k in [3u32, 4, 5] {
            let truss = ktruss(g, k, Mode::Fine).truss;
            if truss.nnz() == 0 {
                continue;
            }
            let sup = triangle::edge_supports_naive(&truss);
            if let Some(&bad) = sup.iter().find(|&&s| s < k - 2) {
                return Err(format!("k={k}: edge with support {bad} survived"));
            }
        }
        Ok(())
    });
}

/// truss(k+1) ⊆ truss(k) (nesting).
#[test]
fn prop_truss_nesting() {
    forall(Config::cases(40), arbitrary_graph, |g| {
        let mut prev: Option<HashSet<(u32, u32)>> = None;
        for k in [3u32, 4, 5, 6] {
            let cur: HashSet<(u32, u32)> = ktruss(g, k, Mode::Coarse).truss.edges().collect();
            if let Some(p) = &prev {
                if !cur.is_subset(p) {
                    return Err(format!("truss({k}) not nested in truss({})", k - 1));
                }
            }
            prev = Some(cur);
        }
        Ok(())
    });
}

/// Coarse, fine and the independent naive oracle agree.
#[test]
fn prop_modes_and_oracle_agree() {
    forall(Config::cases(30), arbitrary_graph, |g| {
        for k in [3u32, 5] {
            let coarse: Vec<_> = ktruss(g, k, Mode::Coarse).truss.edges().collect();
            let fine: Vec<_> = ktruss(g, k, Mode::Fine).truss.edges().collect();
            let naive = reference::ktruss_naive(g, k);
            if coarse != fine {
                return Err(format!("k={k}: coarse != fine"));
            }
            if coarse != naive {
                return Err(format!("k={k}: eager != naive oracle"));
            }
        }
        Ok(())
    });
}

/// The k-truss is a fixpoint: running k-truss on its own output changes
/// nothing.
#[test]
fn prop_truss_is_fixpoint() {
    forall(Config::cases(30), arbitrary_graph, |g| {
        let once = ktruss(g, 4, Mode::Fine).truss;
        let twice = ktruss(&once, 4, Mode::Fine).truss;
        if once != twice {
            return Err("k-truss not a fixpoint".into());
        }
        Ok(())
    });
}

/// Support sum is exactly 3× the triangle count, on every graph.
#[test]
fn prop_support_sum_is_three_triangles() {
    forall(Config::cases(40), arbitrary_graph, |g| {
        let z = ZCsr::from_csr(g);
        let mut s = Vec::new();
        compute_supports_seq(&z, &mut s);
        let total: u64 = s.iter().map(|&x| x as u64).sum();
        let tri = triangle::count_triangles(g);
        if total != 3 * tri {
            return Err(format!("sum(S)={total} != 3*{tri}"));
        }
        Ok(())
    });
}

/// The zero-terminated working form stays structurally valid after the
/// full convergence loop (compaction invariant).
#[test]
fn prop_zcsr_valid_after_convergence() {
    forall(Config::cases(30), arbitrary_graph, |g| {
        let mut z = ZCsr::from_csr(g);
        let mut s = Vec::new();
        ktruss::algo::ktruss::run_to_convergence(&mut z, &mut s, 4);
        validate::check_zcsr(&z).map_err(|e| format!("invalid zcsr: {e}"))?;
        validate::check(&z.to_csr()).map_err(|e| format!("invalid csr: {e}"))?;
        Ok(())
    });
}

/// kmax from the incremental search equals the decomposition's kmax,
/// and both bound every edge's trussness.
#[test]
fn prop_kmax_consistency() {
    forall(Config::cases(20), arbitrary_graph, |g| {
        let km = kmax::kmax(g);
        let d = decompose::decompose(g);
        if g.nnz() > 0 && km.kmax != d.kmax {
            return Err(format!("kmax {} != decompose kmax {}", km.kmax, d.kmax));
        }
        if let Some((&e, &t)) = d.trussness.iter().find(|&(_, &t)| t > d.kmax) {
            return Err(format!("edge {e:?} trussness {t} exceeds kmax"));
        }
        Ok(())
    });
}

/// IO round-trips preserve the graph exactly (TSV and binary).
#[test]
fn prop_io_roundtrip() {
    forall(Config::cases(25), arbitrary_graph, |g| {
        let mut tsv = Vec::new();
        ktruss::graph::io::write_edge_list(g, &mut tsv).map_err(|e| e.to_string())?;
        let g2 = ktruss::graph::io::read_edge_list(tsv.as_slice()).map_err(|e| e.to_string())?;
        // vertex-id compaction may shrink isolated tail vertices, so
        // compare edges, not the struct
        let a: Vec<_> = g.edges().collect();
        let b: Vec<_> = g2.edges().collect();
        // relabeling is identity when ids are dense; compare counts +
        // triangle census as a structure fingerprint
        if a.len() != b.len() {
            return Err("edge count changed through tsv".into());
        }
        if triangle::count_triangles(g) != triangle::count_triangles(&g2) {
            return Err("triangle census changed through tsv".into());
        }
        let mut bin = Vec::new();
        ktruss::graph::io::write_binary(g, &mut bin).map_err(|e| e.to_string())?;
        let g3 = ktruss::graph::io::read_binary(bin.as_slice()).map_err(|e| e.to_string())?;
        if &g3 != g {
            return Err("binary roundtrip not identical".into());
        }
        Ok(())
    });
}

/// Relabeling vertices never changes truss sizes or kmax (isomorphism
/// invariance of the whole pipeline).
#[test]
fn prop_relabel_invariance() {
    forall(Config::cases(20), arbitrary_graph, |g| {
        let r = ktruss::graph::builder::relabel_by_degree(g);
        for k in [3u32, 4] {
            let a = ktruss(g, k, Mode::Fine).truss.nnz();
            let b = ktruss(&r, k, Mode::Fine).truss.nnz();
            if a != b {
                return Err(format!("k={k}: truss size {a} vs {b} after relabel"));
            }
        }
        if kmax::kmax(g).kmax != kmax::kmax(&r).kmax {
            return Err("kmax changed under relabeling".into());
        }
        Ok(())
    });
}

/// Simulated makespan obeys its bounds: critical path ≤ makespan and
/// makespan ≤ total work (both schedules), for every graph.
#[test]
fn prop_makespan_bounds() {
    use ktruss::cost::trace::trace_supports;
    use ktruss::par::Schedule;
    use ktruss::sim::cpu::makespan_ns;
    forall(Config::cases(25), arbitrary_graph, |g| {
        let z = ZCsr::from_csr(g);
        let mut s = Vec::new();
        let tr = trace_supports(&z, &mut s);
        let costs: Vec<f64> = tr.fine_steps.iter().map(|&x| x as f64 + 1.0).collect();
        let total: f64 = costs.iter().sum();
        let critical = costs.iter().cloned().fold(0.0f64, f64::max);
        for sched in [
            Schedule::Static,
            Schedule::Dynamic { chunk: 8 },
            Schedule::WorkAware,
            Schedule::Stealing,
        ] {
            for threads in [1usize, 4, 48] {
                let m = makespan_ns(&costs, threads, sched);
                if m > total * 1.01 + 1.0 {
                    return Err(format!("makespan {m} exceeds total {total}"));
                }
                if m + 1.0 < critical {
                    return Err(format!("makespan {m} below critical path {critical}"));
                }
                if threads == 1 && (m - total).abs() > total * 0.02 + 1.0 {
                    return Err(format!("1-thread makespan {m} != total {total}"));
                }
            }
        }
        Ok(())
    });
}

/// The parallel (pool) execution agrees with sequential for every graph
/// and every schedule — the atomics are race-free by construction.
/// (The exhaustive schedule × generator sweep lives in prop_balance.rs.)
#[test]
fn prop_parallel_matches_sequential() {
    use ktruss::par::{compute_supports_par, Pool, Schedule};
    forall(Config::cases(15), arbitrary_graph, |g| {
        let z = ZCsr::from_csr(g);
        let mut want = Vec::new();
        compute_supports_seq(&z, &mut want);
        let pool = Pool::new(3);
        for mode in [Mode::Coarse, Mode::Fine] {
            for sched in [Schedule::Dynamic { chunk: 7 }, Schedule::WorkAware, Schedule::Stealing] {
                let got = compute_supports_par(&z, &pool, mode, sched);
                if got != want {
                    return Err(format!("{mode} {sched:?}: parallel supports diverge"));
                }
            }
        }
        Ok(())
    });
}

/// Generators deliver exactly the requested sizes and valid structure
/// across their parameter space.
#[test]
fn prop_generators_honor_contracts() {
    forall(
        Config::cases(25),
        |rng| {
            let n = rng.range(16, 400);
            let m = rng.range(n / 2, 3 * n);
            let fam = rng.below(4);
            (n, m, fam, rng.split())
        },
        |&(n, m, fam, ref rng)| {
            let mut rng = rng.clone();
            let g: Csr = match fam {
                0 => ktruss::gen::erdos_renyi::gnm(n, m, &mut rng),
                1 => ktruss::gen::rmat::rmat(n, m, ktruss::gen::rmat::RmatParams::social(), &mut rng),
                2 => ktruss::gen::community::communities(n, m, 16, &mut rng),
                _ => ktruss::gen::barabasi_albert::ba_closure(n.max(8), m, 0.3, &mut rng),
            };
            if g.nnz() != m {
                return Err(format!("family {fam}: m {} != requested {m}", g.nnz()));
            }
            validate::check(&g).map_err(|e| format!("family {fam}: {e}"))?;
            Ok(())
        },
    );
}
