//! Property and integration tests of the incremental frontier-driven
//! support maintenance (`algo::incremental`, `par::frontier`): the
//! incremental and auto drivers must be indistinguishable from full
//! recompute — identical trusses, identical iteration counts, exact
//! maintained supports — across every generator family, schedule,
//! granularity, and k, while doing strictly less work on cascades.

use ktruss::algo::incremental::{
    compact_preserving, decrement_frontier_seq, mark_frontier, InNbrs, SupportMode,
};
use ktruss::algo::ktruss::{ktruss_mode, Mode};
use ktruss::algo::support::compute_supports_seq;
use ktruss::gen::suite;
use ktruss::graph::{validate, ZCsr};
use ktruss::par::{ktruss_par_gran_mode, ktruss_par_mode, Pool, Schedule};
use ktruss::testkit::graphs::{
    arbitrary_graph, clique_with_tail, diamond, hub_divergence_comb, path, peel_chain,
    star_with_fringe,
};
use ktruss::testkit::{forall, Config};

const MODES: [SupportMode; 3] =
    [SupportMode::Full, SupportMode::Incremental, SupportMode::Auto];

/// All support modes produce the identical truss and iteration count on
/// random graphs from every generator family, for k ∈ {3,4,5,8}.
#[test]
fn prop_support_modes_agree_on_all_families() {
    forall(Config::cases(30), arbitrary_graph, |g| {
        for k in [3u32, 4, 5, 8] {
            let full = ktruss_mode(g, k, Mode::Fine, SupportMode::Full);
            for support in [SupportMode::Incremental, SupportMode::Auto] {
                let r = ktruss_mode(g, k, Mode::Fine, support);
                if r.truss != full.truss {
                    return Err(format!("k={k} {support}: truss mismatch"));
                }
                if r.iterations != full.iterations {
                    return Err(format!(
                        "k={k} {support}: {} iterations vs full's {}",
                        r.iterations, full.iterations
                    ));
                }
            }
        }
        Ok(())
    });
}

/// One incremental round equals prune + full recompute, slot for slot,
/// on random graphs (the maintained supports are *exact*, not just
/// threshold-equivalent).
#[test]
fn prop_one_round_supports_are_exact() {
    forall(Config::cases(30), arbitrary_graph, |g| {
        let z0 = ZCsr::from_csr(g);
        let mut s0 = Vec::new();
        compute_supports_seq(&z0, &mut s0);
        let in_nbrs = InNbrs::build(&z0);
        for k in [3u32, 4, 5, 8] {
            // incremental round
            let mut z_inc = z0.clone();
            let mut s_inc = s0.clone();
            let f = mark_frontier(&z_inc, &s_inc, k);
            decrement_frontier_seq(&z_inc, &mut s_inc, &f, &in_nbrs);
            compact_preserving(&mut z_inc, &mut s_inc, &f.dying);
            if validate::check_zcsr(&z_inc).is_err() {
                return Err(format!("k={k}: compaction broke the working form"));
            }
            // reference: classic prune + recompute
            let mut z_ref = z0.clone();
            let mut s_ref = s0.clone();
            ktruss::algo::prune::prune(&mut z_ref, &mut s_ref, k);
            compute_supports_seq(&z_ref, &mut s_ref);
            if z_inc != z_ref {
                return Err(format!("k={k}: working forms diverged"));
            }
            if s_inc != s_ref {
                return Err(format!("k={k}: maintained supports diverged"));
            }
        }
        Ok(())
    });
}

/// The parallel drivers agree with the sequential ones in every support
/// mode, across schedules and granularities, on random graphs.
#[test]
fn prop_par_mode_drivers_agree() {
    let pool = Pool::new(4);
    forall(Config::cases(12), arbitrary_graph, |g| {
        for k in [3u32, 5] {
            let want = ktruss_mode(g, k, Mode::Fine, SupportMode::Full);
            for support in MODES {
                for sched in [Schedule::Static, Schedule::WorkAware, Schedule::Stealing] {
                    let r = ktruss_par_mode(g, k, &pool, Mode::Fine, sched, support);
                    if r.truss != want.truss {
                        return Err(format!("k={k} {support} {sched:?}: truss mismatch"));
                    }
                    if r.iterations != want.iterations {
                        return Err(format!("k={k} {support} {sched:?}: iteration mismatch"));
                    }
                }
                let r = ktruss_par_gran_mode(
                    g,
                    k,
                    &pool,
                    ktruss::algo::support::Granularity::Segment { len: 8 },
                    Schedule::WorkAware,
                    support,
                );
                if r.truss != want.truss {
                    return Err(format!("k={k} {support} segment: truss mismatch"));
                }
                let r = ktruss_par_gran_mode(
                    g,
                    k,
                    &pool,
                    ktruss::algo::support::Granularity::Coarse,
                    Schedule::Stealing,
                    support,
                );
                if r.truss != want.truss {
                    return Err(format!("k={k} {support} coarse: truss mismatch"));
                }
            }
        }
        Ok(())
    });
}

/// Replica-suite graphs (one small instance per family) agree across
/// modes end to end.
#[test]
fn suite_families_agree_across_modes() {
    for spec in suite::small_suite() {
        let g = suite::load(spec, 0.04).expect("suite graph generates");
        for k in [3u32, 5] {
            let full = ktruss_mode(&g, k, Mode::Fine, SupportMode::Full);
            for support in [SupportMode::Incremental, SupportMode::Auto] {
                let r = ktruss_mode(&g, k, Mode::Fine, support);
                assert_eq!(r.truss, full.truss, "{} k={k} {support}", spec.name);
                assert_eq!(
                    r.iterations, full.iterations,
                    "{} k={k} {support}",
                    spec.name
                );
            }
        }
    }
}

/// Fixture edge cases: empty frontier from the start, all edges dying
/// in one pass, tombstone-heavy intermediate states, the hub comb, and
/// the serial peel chain.
#[test]
fn fixture_edge_cases_agree_across_modes() {
    let fixtures = vec![
        ("diamond", diamond()),
        ("path", path(12)),
        ("clique-tail", clique_with_tail()),
        ("star-fringe", star_with_fringe(60)),
        ("hub-comb", hub_divergence_comb(20, 30, 64)),
        ("peel-chain", peel_chain(10)),
    ];
    let pool = Pool::new(3);
    for (name, g) in &fixtures {
        for k in [3u32, 4, 5, 8] {
            let full = ktruss_mode(g, k, Mode::Fine, SupportMode::Full);
            for support in [SupportMode::Incremental, SupportMode::Auto] {
                let seq = ktruss_mode(g, k, Mode::Fine, support);
                assert_eq!(seq.truss, full.truss, "{name} k={k} {support}");
                assert_eq!(seq.iterations, full.iterations, "{name} k={k} {support}");
                let par =
                    ktruss_par_mode(g, k, &pool, Mode::Fine, Schedule::WorkAware, support);
                assert_eq!(par.truss, full.truss, "{name} k={k} {support} par");
            }
        }
    }
}

/// The deterministic deep cascade: ≥ 4 iterations, identical truss, and
/// the incremental driver reduces total merge-steps by ≥ 3x — the
/// acceptance bar the CI cascade smoke also enforces.
#[test]
fn peel_chain_cascade_reduces_steps_3x() {
    let g = peel_chain(40);
    let full = ktruss_mode(&g, 4, Mode::Fine, SupportMode::Full);
    let inc = ktruss_mode(&g, 4, Mode::Fine, SupportMode::Incremental);
    let auto = ktruss_mode(&g, 4, Mode::Fine, SupportMode::Auto);
    assert!(full.iterations >= 4, "iterations {}", full.iterations);
    assert_eq!(inc.truss, full.truss);
    assert_eq!(auto.truss, full.truss);
    let (fs, is, as_) = (
        full.total_support_steps(),
        inc.total_support_steps(),
        auto.total_support_steps(),
    );
    assert!(
        is * 3 <= fs,
        "expected >= 3x step reduction: incremental {is} vs full {fs}"
    );
    // auto tracks the incremental driver here (its crossover estimate
    // is tiny) and never exceeds full recompute
    assert!(as_ <= fs, "auto {as_} vs full {fs}");
    // every post-initial iteration of the forced-incremental driver is
    // flagged as such, and the flags survive into the stats
    assert!(!inc.stats[0].incremental);
    assert!(inc.stats.iter().skip(1).all(|s| s.incremental));
}

/// Warm k-level chaining (kmax/decompose) stays consistent with direct
/// per-k computation under the incremental default.
#[test]
fn warm_chained_kmax_matches_direct() {
    forall(Config::cases(10), arbitrary_graph, |g| {
        let r = ktruss::algo::kmax::kmax(g);
        if g.nnz() == 0 {
            return Ok(());
        }
        let direct = ktruss_mode(g, r.kmax.max(3), Mode::Fine, SupportMode::Full);
        if r.kmax >= 3 && r.truss != direct.truss {
            return Err(format!("kmax={} truss mismatch", r.kmax));
        }
        // one higher k must be empty
        let above = ktruss_mode(g, r.kmax + 1, Mode::Fine, SupportMode::Auto);
        if r.kmax >= 3 && !above.is_empty() {
            return Err(format!("truss at k={} should be empty", r.kmax + 1));
        }
        Ok(())
    });
}
