//! Property tests for the lockstep-lane execution backend
//! ([`ktruss::exec::lane`]) — the contract that makes "execute the GPU
//! plan for real" trustworthy:
//!
//! * lane-executed supports and trusses are **bit-identical** to the
//!   CPU pool backend across the full plan grid (every schedule, every
//!   granularity, every support mode) — the backend may only change who
//!   runs which probe, never a single count;
//! * dispatching through [`ktruss::par::ktruss_par_plan`] with a
//!   GPU-device plan takes the lane path and agrees with calling the
//!   lane driver directly;
//! * the lane report's measured warp durations reproduce the machine
//!   model's [`ktruss::sim::gpu::warp_durations`] exactly when fed the
//!   measured per-task steps — the model and the execution share one
//!   accounting, which is what lets the calibration loop compare them.

use ktruss::algo::incremental::SupportMode;
use ktruss::algo::support::Granularity;
use ktruss::exec::lane::{compute_supports_lane, ktruss_lane, WARP_LANES};
use ktruss::graph::ZCsr;
use ktruss::par::{compute_supports_gran, ktruss_par_plan, Pool, Schedule, ALL_SCHEDULES};
use ktruss::plan::{ExecutionPlan, PlanDevice};
use ktruss::sim::gpu::warp_durations;
use ktruss::sim::machine::GpuMachine;
use ktruss::testkit::graphs::{arbitrary_graph, clique_with_tail, hub_divergence_comb, peel_chain};
use ktruss::testkit::{forall, Config};

/// Granularities the parity grid sweeps (one of each task shape).
const GRANULARITIES: [Granularity; 4] = [
    Granularity::Coarse,
    Granularity::Fine,
    Granularity::Segment { len: 8 },
    Granularity::Hybrid { len: 8 },
];

#[test]
fn prop_lane_supports_bit_identical_to_pool() {
    forall(Config::cases(10), arbitrary_graph, |g| {
        let z = ZCsr::from_csr(g);
        let pool = Pool::new(4);
        for gran in GRANULARITIES {
            for sched in ALL_SCHEDULES {
                let want = compute_supports_gran(&z, &pool, gran, sched);
                let (got, r) = compute_supports_lane(&z, &pool, gran, sched);
                if got != want {
                    return Err(format!("{gran} {sched:?}: lane supports diverge from pool"));
                }
                // internal accounting invariants of the report
                if r.executed_steps != r.task_steps.iter().sum::<u64>() {
                    return Err(format!("{gran} {sched:?}: executed != Σ task_steps"));
                }
                if r.warp_steps != r.warp_durations.iter().sum::<u64>() {
                    return Err(format!("{gran} {sched:?}: warp_steps != Σ durations"));
                }
                if r.warps != r.tasks.div_ceil(WARP_LANES) {
                    return Err(format!("{gran} {sched:?}: warp count off"));
                }
                if r.executed_steps > r.warp_steps.saturating_mul(WARP_LANES as u64) {
                    return Err(format!("{gran} {sched:?}: lanes did more than warps paid"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lane_warp_durations_match_machine_model_exactly() {
    // the calibration loop's premise: feed the measured per-task steps
    // through the model's warp aggregation and get the measured warp
    // durations back, element for element (u64 step counts are exact
    // in f64 far beyond any graph here)
    let m = GpuMachine::v100();
    assert_eq!(m.warp_size, WARP_LANES, "model and backend disagree on warp width");
    forall(Config::cases(10), arbitrary_graph, |g| {
        let z = ZCsr::from_csr(g);
        let pool = Pool::new(4);
        for gran in [Granularity::Fine, Granularity::Hybrid { len: 4 }] {
            let (_, r) = compute_supports_lane(&z, &pool, gran, Schedule::Static);
            let costs: Vec<f64> = r.task_steps.iter().map(|&s| s as f64).collect();
            let model = warp_durations(&m, &costs);
            if model.len() != r.warp_durations.len() {
                return Err(format!(
                    "{gran}: model sees {} warps, backend measured {}",
                    model.len(),
                    r.warp_durations.len()
                ));
            }
            for (i, (&ms, &es)) in model.iter().zip(&r.warp_durations).enumerate() {
                if ms != es as f64 {
                    return Err(format!(
                        "{gran}: warp {i} model duration {ms} != executed {es}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lane_truss_matches_pool_across_plan_grid() {
    // end-to-end parity on fixtures that exercise deep peeling, hub
    // divergence and a dense core: same truss, same convergence
    // iteration count, whether the plan executes on the pool or the
    // lane backend — and whether the lane backend is reached directly
    // or through the plan dispatcher
    let pool = Pool::new(4);
    let fixtures = [
        ("peel_chain", peel_chain(12)),
        ("hub_comb", hub_divergence_comb(32, 128, 400)),
        ("clique_tail", clique_with_tail()),
    ];
    for (name, g) in &fixtures {
        for k in [3u32, 4, 8] {
            for sched in [Schedule::Static, Schedule::Stealing] {
                for gran in GRANULARITIES {
                    for support in [SupportMode::Full, SupportMode::Auto] {
                        let cpu_plan = ExecutionPlan::fixed(sched, gran, support);
                        let gpu_plan = ExecutionPlan { device: PlanDevice::Gpu, ..cpu_plan };
                        let want = ktruss_par_plan(g, k, &pool, &cpu_plan);
                        let via_dispatch = ktruss_par_plan(g, k, &pool, &gpu_plan);
                        let direct = ktruss_lane(g, k, &pool, &gpu_plan);
                        assert_eq!(
                            via_dispatch.truss, want.truss,
                            "{name} k={k} {gpu_plan}: dispatched lane truss diverges"
                        );
                        assert_eq!(
                            direct.truss, want.truss,
                            "{name} k={k} {gpu_plan}: direct lane truss diverges"
                        );
                        assert_eq!(
                            via_dispatch.iterations, want.iterations,
                            "{name} k={k} {gpu_plan}: iteration count diverges"
                        );
                        assert_eq!(
                            direct.iterations, want.iterations,
                            "{name} k={k} {gpu_plan}: direct iteration count diverges"
                        );
                    }
                }
            }
        }
    }
}
