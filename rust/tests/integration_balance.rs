//! Integration: the work-aware schedules end-to-end on the workloads
//! they exist for — skewed power-law replicas and adversarial
//! star/hub graphs — plus a stress test for the stealing path's
//! termination in the many-threads-few-tasks corner.

use ktruss::algo::ktruss::ktruss;
use ktruss::algo::support::{compute_supports_seq, Mode};
use ktruss::graph::builder::from_sorted_unique;
use ktruss::graph::{validate, Csr, Vid, ZCsr};
use ktruss::par::{compute_supports_par, ktruss_par, Pool, Schedule};
use ktruss::util::Rng;

/// A star with a triangle fringe: vertex 0 connects to everyone (the
/// pathological hot row for coarse scheduling) and consecutive leaves
/// are chained so triangles (0, i, i+1) exist.
fn star_with_fringe(leaves: usize) -> Csr {
    let mut edges: Vec<(Vid, Vid)> = Vec::new();
    for v in 1..=leaves as Vid {
        edges.push((0, v));
    }
    for v in 1..leaves as Vid {
        edges.push((v, v + 1));
    }
    edges.sort_unstable();
    from_sorted_unique(leaves + 1, &edges)
}

fn skewed_rmat(seed: u64) -> Csr {
    ktruss::gen::rmat::rmat(
        2000,
        14_000,
        ktruss::gen::rmat::RmatParams::autonomous_system(),
        &mut Rng::new(seed),
    )
}

#[test]
fn skewed_rmat_ktruss_matches_sequential_under_new_schedules() {
    let g = skewed_rmat(42);
    let pool = Pool::new(4);
    for k in [3u32, 4] {
        let want = ktruss(&g, k, Mode::Fine);
        for sched in [Schedule::WorkAware, Schedule::Stealing] {
            for mode in [Mode::Coarse, Mode::Fine] {
                let got = ktruss_par(&g, k, &pool, mode, sched);
                assert_eq!(got.truss, want.truss, "k={k} {mode} {sched:?}");
                assert_eq!(got.iterations, want.iterations, "k={k} {mode} {sched:?}");
                assert!(validate::check(&got.truss).is_ok(), "k={k} {mode} {sched:?}");
            }
        }
    }
}

#[test]
fn star_graph_hot_row_all_schedules_agree() {
    // the one-huge-task workload: coarse scheduling puts ~all work in
    // row 0, exactly what work-aware binning must survive
    let g = star_with_fringe(400);
    let z = ZCsr::from_csr(&g);
    let mut want = Vec::new();
    compute_supports_seq(&z, &mut want);
    let pool = Pool::new(4);
    for mode in [Mode::Coarse, Mode::Fine] {
        for sched in [Schedule::WorkAware, Schedule::Stealing] {
            let got = compute_supports_par(&z, &pool, mode, sched);
            assert_eq!(got, want, "{mode} {sched:?}");
        }
    }
    // and the truss itself: every (0,i,i+1) triangle keeps its edges
    let want_truss = ktruss(&g, 3, Mode::Fine);
    for sched in [Schedule::WorkAware, Schedule::Stealing] {
        let got = ktruss_par(&g, 3, &pool, Mode::Coarse, sched);
        assert_eq!(got.truss, want_truss.truss, "{sched:?}");
    }
}

#[test]
fn star_cost_estimate_identifies_the_hot_row() {
    let g = star_with_fringe(300);
    let z = ZCsr::from_csr(&g);
    let costs = ktruss::par::estimate_costs(&z, Mode::Coarse);
    let hot = costs[0];
    let rest_max = costs[1..].iter().max().copied().unwrap_or(0);
    assert!(
        hot > 10 * rest_max.max(1),
        "row 0 estimate {hot} should dwarf the rest (max {rest_max})"
    );
    // and the binner must isolate it: with 4 bins, the hot row's bin
    // carries row 0 alone or nearly so
    let bins = ktruss::par::scan_bins(&costs, 4);
    let hot_bin = bins.iter().find(|&&(lo, hi)| lo == 0 && hi > 0).unwrap();
    let hot_bin_rows = hot_bin.1 - hot_bin.0;
    assert!(
        hot_bin_rows < costs.len() / 2,
        "hot bin spans {hot_bin_rows} rows — binning failed to isolate the hub"
    );
}

#[test]
fn many_threads_few_tasks_terminates() {
    // 32 workers, a graph with 4 rows: most stealing workers find
    // nothing and must exit cleanly (no lost-wakeup/deadlock). Repeat
    // to give races a chance to bite.
    let g = from_sorted_unique(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)]);
    let z = ZCsr::from_csr(&g);
    let mut want = Vec::new();
    compute_supports_seq(&z, &mut want);
    let pool = Pool::new(32);
    for trial in 0..50 {
        for sched in [Schedule::Stealing, Schedule::WorkAware] {
            let got = compute_supports_par(&z, &pool, Mode::Fine, sched);
            assert_eq!(got, want, "trial {trial} {sched:?}");
        }
    }
    // empty graph through the full pooled driver, all schedules
    let empty = Csr::empty(6);
    for sched in [Schedule::Stealing, Schedule::WorkAware] {
        let r = ktruss_par(&empty, 3, &pool, Mode::Fine, sched);
        assert_eq!(r.truss.nnz(), 0, "{sched:?}");
    }
}

#[test]
fn oversubscribed_pool_on_skewed_graph() {
    // more workers than a small skewed graph can feed: correctness and
    // termination under heavy stealing contention
    let g = skewed_rmat(7);
    let z = ZCsr::from_csr(&g);
    let mut want = Vec::new();
    compute_supports_seq(&z, &mut want);
    let pool = Pool::new(16);
    let got = compute_supports_par(&z, &pool, Mode::Coarse, Schedule::Stealing);
    assert_eq!(got, want);
}
