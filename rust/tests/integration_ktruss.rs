//! Integration: the full sparse pipeline on suite replicas — generator
//! → CSR → zero-terminated form → eager K-truss (both granularities,
//! sequential and pooled) → oracle cross-checks.

use ktruss::algo::ktruss::ktruss;
use ktruss::algo::support::Mode;
use ktruss::algo::{kmax, reference, triangle};
use ktruss::gen::suite;
use ktruss::graph::validate;
use ktruss::par::{ktruss_par, Pool, Schedule};

const SCALE: f64 = 0.04;

#[test]
fn suite_replicas_all_families_run_clean() {
    // one representative per family
    for name in [
        "ca-GrQc",          // Collab
        "p2p-Gnutella08",   // P2p
        "as20000102",       // AutonomousSystem
        "soc-Epinions1",    // Social
        "amazon0302",       // Copurchase
        "roadNet-PA",       // Road
    ] {
        let spec = suite::by_name(name).unwrap();
        let g = suite::generate(spec, SCALE);
        validate::check(&g).unwrap_or_else(|e| panic!("{name}: {e}"));
        let r3 = ktruss(&g, 3, Mode::Fine);
        let rc = ktruss(&g, 3, Mode::Coarse);
        assert_eq!(r3.truss, rc.truss, "{name}: modes disagree");
        // truss edge supports are internally consistent
        if r3.truss.nnz() > 0 {
            let sup = triangle::edge_supports_naive(&r3.truss);
            assert!(sup.iter().all(|&s| s >= 1), "{name}: 3-truss edge without triangle");
        }
    }
}

#[test]
fn pooled_matches_sequential_on_replicas() {
    let pool = Pool::new(4);
    for name in ["oregon1_010331", "ca-HepTh", "p2p-Gnutella04"] {
        let spec = suite::by_name(name).unwrap();
        let g = suite::generate(spec, SCALE);
        for k in [3u32, 4] {
            let seq = ktruss(&g, k, Mode::Fine);
            for mode in [Mode::Coarse, Mode::Fine] {
                for sched in [Schedule::Static, Schedule::Dynamic { chunk: 128 }] {
                    let par = ktruss_par(&g, k, &pool, mode, sched);
                    assert_eq!(par.truss, seq.truss, "{name} k={k} {mode} {sched:?}");
                }
            }
        }
    }
}

#[test]
fn naive_oracle_agrees_on_small_replicas() {
    for name in ["ca-GrQc", "as20000102"] {
        let spec = suite::by_name(name).unwrap();
        let g = suite::generate(spec, 0.02);
        for k in [3u32, 4, 5] {
            let eager: Vec<_> = ktruss(&g, k, Mode::Fine).truss.edges().collect();
            let naive = reference::ktruss_naive(&g, k);
            assert_eq!(eager, naive, "{name} k={k}");
        }
    }
}

#[test]
fn kmax_values_are_family_plausible() {
    // collaboration replicas are clique-rich (high kmax); road replicas
    // are triangle-poor (kmax <= 4); gnutella is ER-like (kmax <= 5)
    let k = |name: &str, scale: f64| {
        let g = suite::generate(suite::by_name(name).unwrap(), scale);
        kmax::kmax(&g).kmax
    };
    let collab = k("ca-GrQc", 0.05);
    let road = k("roadNet-PA", 0.05);
    let p2p = k("p2p-Gnutella08", 0.05);
    assert!(collab >= 8, "collab kmax {collab}");
    assert!(road <= 4, "road kmax {road}");
    assert!(p2p <= 5, "p2p kmax {p2p}");
}

#[test]
fn iteration_counts_decrease_edges_monotonically() {
    let g = suite::generate(suite::by_name("oregon2_010331").unwrap(), SCALE);
    let r = ktruss(&g, 4, Mode::Fine);
    assert_eq!(r.stats.len(), r.iterations);
    for w in r.stats.windows(2) {
        assert!(w[1].live_edges < w[0].live_edges, "live edges must shrink");
        assert_eq!(w[1].live_edges, w[0].live_edges - w[0].removed);
    }
    // last iteration removed nothing (convergence) unless truss emptied
    let last = r.stats.last().unwrap();
    assert!(last.removed == 0 || last.live_edges == last.removed);
}

#[test]
fn graph_cache_roundtrip_at_scale() {
    let dir = std::env::temp_dir().join(format!("ktruss-cache-{}", std::process::id()));
    std::env::set_var("KTRUSS_GRAPH_CACHE", &dir);
    let spec = suite::by_name("ca-HepTh").unwrap();
    let a = suite::load(spec, 0.03).unwrap(); // generates + writes
    let b = suite::load(spec, 0.03).unwrap(); // reads back
    assert_eq!(a, b);
    std::env::remove_var("KTRUSS_GRAPH_CACHE");
    let _ = std::fs::remove_dir_all(&dir);
}
