//! End-to-end CLI tests: drive the actual `ktruss` binary the way a
//! user would (cargo exposes the built binary path via CARGO_BIN_EXE_*).

use std::process::Command;

fn ktruss(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_ktruss"))
        .args(args)
        .output()
        .expect("run ktruss binary");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn help_lists_commands() {
    let (stdout, _, ok) = ktruss(&["help"]);
    assert!(ok);
    for cmd in ["run", "kmax", "decompose", "generate", "suite", "bench", "serve", "plan", "sim"] {
        assert!(stdout.contains(cmd), "help missing {cmd}");
    }
    for flag in ["--granularity", "--gpu-schedule", "gpu-sched", "--plan", "bench plan"] {
        assert!(stdout.contains(flag), "help missing {flag}");
    }
}

#[test]
fn unknown_command_exits_nonzero() {
    let (_, _, ok) = ktruss(&["frobnicate"]);
    assert!(!ok);
}

#[test]
fn unknown_flag_is_rejected() {
    let (_, stderr, ok) = ktruss(&["run", "--graph", "ca-GrQc", "--scale", "0.05", "--tpyo", "x"]);
    assert!(!ok);
    assert!(stderr.contains("unknown flag"), "stderr: {stderr}");
}

#[test]
fn run_on_suite_graph_reports_truss() {
    let (stdout, stderr, ok) =
        ktruss(&["run", "--graph", "p2p-Gnutella08", "--k", "3", "--scale", "0.05"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("3-truss:"), "stdout: {stdout}");
    assert!(stdout.contains("iterations"));
}

#[test]
fn kmax_and_decompose_agree_via_cli() {
    let (km_out, _, ok1) = ktruss(&["kmax", "--graph", "ca-GrQc", "--scale", "0.05"]);
    let (de_out, _, ok2) = ktruss(&["decompose", "--graph", "ca-GrQc", "--scale", "0.05"]);
    assert!(ok1 && ok2);
    let grab = |s: &str| -> u32 {
        s.lines()
            .find(|l| l.contains("kmax ="))
            .and_then(|l| l.split('=').nth(1))
            .and_then(|v| v.trim().split(|c: char| !c.is_ascii_digit()).next())
            .and_then(|v| v.parse().ok())
            .expect("kmax value")
    };
    assert_eq!(grab(&km_out), grab(&de_out));
}

#[test]
fn generate_writes_loadable_file() {
    let dir = std::env::temp_dir().join(format!("ktruss-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("g.tsv");
    let (_, stderr, ok) = ktruss(&[
        "generate",
        "--graph",
        "as20000102",
        "--scale",
        "0.05",
        "--out",
        path.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");
    // round-trip through `run --graph <file>`
    let (stdout, stderr, ok) = ktruss(&["run", "--graph", path.to_str().unwrap(), "--k", "3"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("3-truss:"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn run_accepts_every_schedule() {
    for sched in ["static", "dynamic:64", "workaware", "stealing"] {
        let (stdout, stderr, ok) = ktruss(&[
            "run",
            "--graph",
            "as20000102",
            "--k",
            "3",
            "--scale",
            "0.05",
            "--par",
            "2",
            "--schedule",
            sched,
        ]);
        assert!(ok, "--schedule {sched}: {stderr}");
        assert!(stdout.contains("3-truss:"), "--schedule {sched}: {stdout}");
    }
}

#[test]
fn run_accepts_every_granularity() {
    let mut edge_lines: Vec<String> = Vec::new();
    for gran in ["coarse", "fine", "segment", "segment:16"] {
        let (stdout, stderr, ok) = ktruss(&[
            "run",
            "--graph",
            "as20000102",
            "--k",
            "3",
            "--scale",
            "0.05",
            "--par",
            "2",
            "--granularity",
            gran,
        ]);
        assert!(ok, "--granularity {gran}: {stderr}");
        assert!(stdout.contains("3-truss:"), "--granularity {gran}: {stdout}");
        let line = stdout
            .lines()
            .find(|l| l.contains("3-truss:"))
            .unwrap()
            .split(',')
            .next()
            .unwrap()
            .to_string();
        edge_lines.push(line);
    }
    // every granularity must report the identical surviving edge count
    assert!(
        edge_lines.windows(2).all(|w| w[0] == w[1]),
        "granularities disagree: {edge_lines:?}"
    );
    // segment runs announce the segmented engine
    let (stdout, stderr, ok) = ktruss(&[
        "run", "--graph", "ca-GrQc", "--scale", "0.05", "--granularity", "segment:32",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("segment:32"), "stdout: {stdout}");
}

#[test]
fn run_rejects_bad_granularity_combinations() {
    let (_, stderr, ok) = ktruss(&[
        "run", "--graph", "ca-GrQc", "--scale", "0.05", "--granularity", "bogus",
    ]);
    assert!(!ok);
    assert!(stderr.contains("granularity"), "stderr: {stderr}");
    let (_, stderr, ok) = ktruss(&[
        "run", "--graph", "ca-GrQc", "--scale", "0.05", "--granularity", "segment", "--shards",
        "2",
    ]);
    assert!(!ok);
    assert!(stderr.contains("shards"), "stderr: {stderr}");
}

#[test]
fn sim_reports_schedule_granularity_grid() {
    let (stdout, stderr, ok) = ktruss(&[
        "sim",
        "--graph",
        "as20000102",
        "--scale",
        "0.05",
        "--granularity",
        "all",
        "--gpu-schedule",
        "all",
    ]);
    assert!(ok, "stderr: {stderr}");
    for label in ["GPU-C", "GPU-F", "GPU-S64", "workaware", "stealing", "vs static"] {
        assert!(stdout.contains(label), "missing {label}: {stdout}");
    }
}

#[test]
fn sim_single_schedule_keeps_static_baseline() {
    let (stdout, stderr, ok) = ktruss(&[
        "sim",
        "--graph",
        "as20000102",
        "--scale",
        "0.05",
        "--granularity",
        "fine",
        "--gpu-schedule",
        "work-aware",
        "--cpu-threads",
        "48",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("GPU-F"), "stdout: {stdout}");
    assert!(stdout.contains("GPU-F-workaware"), "stdout: {stdout}");
    assert!(stdout.contains("CPU-F-48t"), "stdout: {stdout}");
}

#[test]
fn sim_rejects_bad_gpu_schedule() {
    let (_, stderr, ok) = ktruss(&[
        "sim", "--graph", "ca-GrQc", "--scale", "0.05", "--gpu-schedule", "bogus",
    ]);
    assert!(!ok);
    assert!(stderr.contains("gpu-schedule"), "stderr: {stderr}");
}

#[test]
fn run_rejects_bad_schedule() {
    let (_, stderr, ok) = ktruss(&[
        "run", "--graph", "ca-GrQc", "--scale", "0.05", "--par", "2", "--schedule", "bogus",
    ]);
    assert!(!ok);
    assert!(stderr.contains("schedule"), "stderr: {stderr}");
}

#[test]
fn serve_accepts_schedule_override() {
    let (stdout, stderr, ok) =
        ktruss(&["serve", "--jobs", "6", "--pool", "2", "--schedule", "workaware"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("schedule=workaware"), "stdout: {stdout}");
    assert!(stdout.contains("all 6 jobs completed"), "stdout: {stdout}");
}

#[test]
fn serve_sharded_with_priorities_and_deadlines() {
    let (stdout, stderr, ok) = ktruss(&[
        "serve", "--jobs", "8", "--shards", "2", "--pool", "2", "--priority", "high",
        "--deadline-ms", "5000",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("shards=2"), "stdout: {stdout}");
    assert!(stdout.contains("all 8 jobs completed"), "stdout: {stdout}");
    assert!(stdout.contains("shard 0:"), "stdout: {stdout}");
    assert!(stdout.contains("shard 1:"), "stdout: {stdout}");
    assert!(stdout.contains("cost model:"), "stdout: {stdout}");
}

#[test]
fn serve_rejects_bad_priority() {
    let (_, stderr, ok) = ktruss(&["serve", "--jobs", "2", "--priority", "urgent"]);
    assert!(!ok);
    assert!(stderr.contains("priority"), "stderr: {stderr}");
}

#[test]
fn serve_persists_calibration_roundtrip() {
    let dir = std::env::temp_dir().join(format!("ktruss-serve-cal-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cal.tsv");
    let path_s = path.to_str().unwrap();
    let (stdout, stderr, ok) =
        ktruss(&["serve", "--jobs", "4", "--shards", "1", "--calibration", path_s]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("calibration: saved"), "stdout: {stdout}");
    assert!(path.exists());
    // second run seeds from the saved records
    let (stdout, stderr, ok) =
        ktruss(&["serve", "--jobs", "4", "--shards", "1", "--calibration", path_s]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("calibration: seeded from"), "stdout: {stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn run_through_sharded_executor() {
    let (stdout, stderr, ok) = ktruss(&[
        "run", "--graph", "ca-GrQc", "--scale", "0.05", "--k", "3", "--par", "2", "--shards",
        "2", "--priority", "high",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("3-truss:"), "stdout: {stdout}");
    assert!(stdout.contains("2-shard executor"), "stdout: {stdout}");
}

#[test]
fn bench_serve_smoke() {
    let dir = std::env::temp_dir().join(format!("ktruss-bench-serve-{}", std::process::id()));
    let (stdout, stderr, ok) = Command::new(env!("CARGO_BIN_EXE_ktruss"))
        .args([
            "bench", "serve", "--jobs", "12", "--arrival-us", "100", "--workers", "2",
            "--shard-counts", "1,2",
        ])
        .env("KTRUSS_BENCH_OUT", &dir)
        .output()
        .map(|out| {
            (
                String::from_utf8_lossy(&out.stdout).into_owned(),
                String::from_utf8_lossy(&out.stderr).into_owned(),
                out.status.success(),
            )
        })
        .expect("run ktruss binary");
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("jobs/s"), "stdout: {stdout}");
    assert!(stdout.contains("serve_throughput.txt"), "stdout: {stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn run_accepts_every_support_mode() {
    let mut edge_lines: Vec<String> = Vec::new();
    for mode in ["full", "incremental", "auto"] {
        let (stdout, stderr, ok) = ktruss(&[
            "run",
            "--graph",
            "as20000102",
            "--k",
            "4",
            "--scale",
            "0.05",
            "--support-mode",
            mode,
        ]);
        assert!(ok, "--support-mode {mode}: {stderr}");
        assert!(stdout.contains("4-truss:"), "--support-mode {mode}: {stdout}");
        assert!(stdout.contains(&format!("support={mode}")), "stdout: {stdout}");
        let line = stdout
            .lines()
            .find(|l| l.contains("4-truss:"))
            .unwrap()
            .split(',')
            .next()
            .unwrap()
            .to_string();
        edge_lines.push(line);
    }
    // every support mode must report the identical surviving edge count
    assert!(
        edge_lines.windows(2).all(|w| w[0] == w[1]),
        "support modes disagree: {edge_lines:?}"
    );
}

#[test]
fn run_rejects_bad_support_mode() {
    let (_, stderr, ok) = ktruss(&[
        "run", "--graph", "ca-GrQc", "--scale", "0.05", "--support-mode", "bogus",
    ]);
    assert!(!ok);
    assert!(stderr.contains("support mode"), "stderr: {stderr}");
}

#[test]
fn sim_supports_incremental_mode() {
    let (stdout, stderr, ok) = ktruss(&[
        "sim",
        "--graph",
        "as20000102",
        "--scale",
        "0.05",
        "--granularity",
        "fine",
        "--gpu-schedule",
        "work-aware",
        "--support-mode",
        "auto",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("support=auto"), "stdout: {stdout}");
    assert!(stdout.contains("GPU-F-workaware"), "stdout: {stdout}");
}

#[test]
fn plan_sweeps_generator_families_by_default() {
    // bare `plan` must print per-candidate predicted costs and a winner
    // for several generator families (the acceptance check)
    let (stdout, stderr, ok) = ktruss(&["plan", "--par", "8"]);
    assert!(ok, "stderr: {stderr}");
    for family in ["rmat-social", "rmat-as-hub", "road-grid", "star-fringe", "hub-comb"] {
        assert!(stdout.contains(family), "missing family {family}: {stdout}");
    }
    assert!(stdout.contains("predicted ms"), "stdout: {stdout}");
    assert!(stdout.contains("<- chosen"), "stdout: {stdout}");
    assert!(
        stdout.matches("chosen: ").count() >= 3,
        "need a winner per family: {stdout}"
    );
}

#[test]
fn plan_explains_one_graph_and_honors_pins() {
    let (stdout, stderr, ok) = ktruss(&[
        "plan", "--graph", "as20000102", "--scale", "0.05", "--par", "4",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("graph:"), "stdout: {stdout}");
    assert!(stdout.contains("<- chosen"), "stdout: {stdout}");
    // pinning the schedule restricts every candidate to it
    let (stdout, stderr, ok) = ktruss(&[
        "plan", "--graph", "as20000102", "--scale", "0.05", "--par", "4", "--plan",
        "workaware/auto/auto",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(!stdout.contains("stealing/"), "stdout: {stdout}");
    assert!(stdout.contains("workaware/"), "stdout: {stdout}");
}

#[test]
fn run_accepts_a_full_plan_spec() {
    let (stdout, stderr, ok) = ktruss(&[
        "run",
        "--graph",
        "as20000102",
        "--k",
        "3",
        "--scale",
        "0.05",
        "--par",
        "2",
        "--plan",
        "stealing/fine/incremental",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("3-truss:"), "stdout: {stdout}");
    assert!(
        stdout.contains("plan=stealing/fine/incremental"),
        "stdout: {stdout}"
    );
}

#[test]
fn run_rejects_bad_plan_spec() {
    let (_, stderr, ok) = ktruss(&[
        "run", "--graph", "ca-GrQc", "--scale", "0.05", "--plan", "bogus",
    ]);
    assert!(!ok);
    assert!(stderr.contains("plan"), "stderr: {stderr}");
}

#[test]
fn run_through_executor_reports_its_plan() {
    let (stdout, stderr, ok) = ktruss(&[
        "run", "--graph", "ca-GrQc", "--scale", "0.05", "--k", "3", "--par", "2", "--shards",
        "2", "--plan", "workaware/fine/auto",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("plan=workaware/fine/auto"), "stdout: {stdout}");
}

#[test]
fn run_rejects_missing_graph_flag() {
    let (_, stderr, ok) = ktruss(&["run"]);
    assert!(!ok);
    assert!(stderr.contains("--graph"), "stderr: {stderr}");
}

#[test]
fn suite_lists_all_fifty() {
    let (stdout, _, ok) = ktruss(&["suite"]);
    assert!(ok);
    assert!(stdout.contains("50"));
    assert!(stdout.contains("cit-Patents"));
    assert!(stdout.contains("roadNet-CA"));
}
