"""L1 correctness: the Pallas support kernel vs the pure-jnp oracle.

This is the core correctness signal for the dense path: hypothesis
sweeps adjacency densities, sizes and tilings; every case must match
``ref.support_ref`` exactly (0/1 inputs → integer-valued f32, so exact
equality is the right assertion, not allclose-with-slop).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from compile.kernels.eager_support import (
    mxu_utilization_estimate,
    support_pallas,
    support_pallas_select,
    vmem_bytes,
)
from compile.kernels.ref import ktruss_fixpoint_ref, ktruss_step_ref, support_ref


def random_symmetric_adjacency(n: int, density: float, seed: int) -> np.ndarray:
    rng = np.random.RandomState(seed)
    upper = (rng.rand(n, n) < density).astype(np.float32)
    upper = np.triu(upper, k=1)
    return upper + upper.T


class TestSupportKernel:
    @pytest.mark.parametrize("n,tile", [(64, 64), (128, 64), (128, 128), (256, 128)])
    def test_matches_ref_dense_sizes(self, n, tile):
        a = random_symmetric_adjacency(n, 0.2, seed=n + tile)
        got = np.asarray(support_pallas(jnp.asarray(a), tile=tile))
        want = np.asarray(support_ref(jnp.asarray(a)))
        np.testing.assert_array_equal(got, want)

    def test_triangle_graph(self):
        # K3 embedded in an 64x64 zero matrix
        a = np.zeros((64, 64), np.float32)
        for u, v in [(0, 1), (0, 2), (1, 2)]:
            a[u, v] = a[v, u] = 1.0
        s = np.asarray(support_pallas(jnp.asarray(a), tile=64))
        for u, v in [(0, 1), (0, 2), (1, 2)]:
            assert s[u, v] == 1.0 and s[v, u] == 1.0
        assert s.sum() == 6.0  # one triangle -> six directed entries

    def test_empty_graph_is_zero(self):
        a = jnp.zeros((128, 128), jnp.float32)
        assert float(jnp.sum(support_pallas(a))) == 0.0

    def test_complete_graph(self):
        n = 64
        a = jnp.asarray(np.ones((n, n), np.float32) - np.eye(n, dtype=np.float32))
        s = np.asarray(support_pallas(a, tile=64))
        # every edge of K_n is in n-2 triangles
        off_diag = ~np.eye(n, dtype=bool)
        assert (s[off_diag] == n - 2).all()
        assert (np.diag(s) == 0).all()

    @settings(max_examples=25, deadline=None)
    @given(
        density=st.floats(min_value=0.0, max_value=0.6),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        tile_pow=st.sampled_from([32, 64]),
        blocks=st.integers(min_value=1, max_value=3),
    )
    def test_hypothesis_sweep(self, density, seed, tile_pow, blocks):
        n = tile_pow * blocks
        a = random_symmetric_adjacency(n, density, seed)
        got = np.asarray(support_pallas(jnp.asarray(a), tile=tile_pow))
        want = np.asarray(support_ref(jnp.asarray(a)))
        np.testing.assert_array_equal(got, want)

    def test_rejects_misaligned_tile(self):
        a = jnp.zeros((100, 100), jnp.float32)
        with pytest.raises(AssertionError):
            support_pallas(a, tile=64)

    @settings(max_examples=10, deadline=None)
    @given(
        density=st.floats(min_value=0.0, max_value=0.5),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_select_masking_variant_identical(self, density, seed):
        # DESIGN.md §8 masking-strategy ablation: mul-mask and
        # select-mask kernels must agree exactly
        a = random_symmetric_adjacency(128, density, seed)
        mul = np.asarray(support_pallas(jnp.asarray(a), tile=64))
        sel = np.asarray(support_pallas_select(jnp.asarray(a), tile=64))
        np.testing.assert_array_equal(mul, sel)


class TestRefSemantics:
    def test_step_prunes_pendant_edge(self):
        a = np.zeros((64, 64), np.float32)
        for u, v in [(0, 1), (0, 2), (1, 2), (2, 3)]:  # triangle + pendant
            a[u, v] = a[v, u] = 1.0
        a_next, removed = ktruss_step_ref(jnp.asarray(a), jnp.float32(1.0))
        assert float(removed) == 2.0  # (2,3) both directions
        assert float(a_next[2, 3]) == 0.0
        assert float(a_next[0, 1]) == 1.0

    def test_fixpoint_of_clique_is_clique(self):
        n = 64
        a = jnp.asarray(np.ones((n, n), np.float32) - np.eye(n, dtype=np.float32))
        out = ktruss_fixpoint_ref(a, jnp.float32(3.0))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(a))

    def test_fixpoint_empties_triangle_free(self):
        a = np.zeros((64, 64), np.float32)
        for u in range(5):  # 6-cycle
            a[u, u + 1] = a[u + 1, u] = 1.0
        a[5, 0] = a[0, 5] = 1.0
        out = ktruss_fixpoint_ref(jnp.asarray(a), jnp.float32(1.0))
        assert float(jnp.sum(out)) == 0.0


class TestPerfEstimates:
    def test_vmem_within_budget(self):
        # 4 tiles of 128x128 f32 = 256 KiB << 16 MiB VMEM
        assert vmem_bytes(128) == 4 * 128 * 128 * 4
        assert vmem_bytes(128) < 16 * 1024 * 1024

    def test_mxu_utilization_monotone(self):
        assert mxu_utilization_estimate(128) == 1.0
        assert mxu_utilization_estimate(64) == 0.25
        assert mxu_utilization_estimate(32) < mxu_utilization_estimate(64)
