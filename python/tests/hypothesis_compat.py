"""Use real hypothesis when available; otherwise a deterministic
fallback so the suite still runs in the offline image (which ships
jax/pytest but not hypothesis).

The fallback keeps the test-authoring surface this suite uses —
``@settings``, ``@given``, ``st.floats`` / ``st.integers`` /
``st.sampled_from`` — and runs each property over a small fixed grid of
boundary + midpoint samples instead of a random search. Deterministic
by construction, so CI never flakes on it.
"""

try:  # pragma: no cover - trivially exercised by import
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # offline image: build the fallback
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A fixed list of representative samples."""

        def __init__(self, samples):
            self.samples = list(samples)

    class _St:
        @staticmethod
        def floats(min_value, max_value, **_kw):
            mid = (min_value + max_value) / 2.0
            return _Strategy([min_value, mid, max_value])

        @staticmethod
        def integers(min_value, max_value, **_kw):
            mid = (min_value + max_value) // 2
            return _Strategy([min_value, mid, max_value])

        @staticmethod
        def sampled_from(elements):
            return _Strategy(elements)

    st = _St()

    def settings(**_kw):
        def deco(f):
            return f

        return deco

    def given(**strategies):
        names = list(strategies)

        def deco(f):
            def wrapper(*args):
                # 5 deterministic cases cycling each strategy's samples
                # out of phase, so combinations vary across cases
                for case in range(5):
                    kwargs = {
                        name: strategies[name].samples[
                            (case + i) % len(strategies[name].samples)
                        ]
                        for i, name in enumerate(names)
                    }
                    f(*args, **kwargs)

            # keep pytest's collection name; deliberately no
            # functools.wraps — pytest must see the (*args) signature,
            # not the wrapped one, or it would treat the property
            # arguments as fixtures
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper

        return deco
