"""L2 semantics: the jax model's ktruss_step against a from-scratch
python K-truss (networkx-free, set-based) on small graphs."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from compile import model


def naive_ktruss(edges, n, k):
    """Set-based K-truss fixpoint (independent of all jax code)."""
    edges = {tuple(sorted(e)) for e in edges}
    while True:
        adj = {u: set() for u in range(n)}
        for u, v in edges:
            adj[u].add(v)
            adj[v].add(u)
        dead = [
            (u, v)
            for (u, v) in edges
            if len(adj[u] & adj[v]) < k - 2
        ]
        if not dead:
            return edges
        edges -= set(dead)


def to_dense(edges, n):
    a = np.zeros((n, n), np.float32)
    for u, v in edges:
        a[u, v] = a[v, u] = 1.0
    return a


def run_dense_fixpoint(a, k, max_iters=64):
    thr = jnp.float32(k - 2)
    a = jnp.asarray(a)
    for _ in range(max_iters):
        a, removed = model.ktruss_step(a, thr, tile=64)
        if float(removed) == 0.0:
            return a
    return a


def dense_to_edges(a):
    a = np.asarray(a)
    return {
        (u, v)
        for u, v in zip(*np.nonzero(np.triu(a, k=1)))
    }


class TestKtrussStep:
    @pytest.mark.parametrize("k", [3, 4, 5])
    def test_matches_naive_on_clique_plus_tail(self, k):
        n = 64
        edges = list(itertools.combinations(range(5), 2)) + [(4, 10), (10, 11)]
        got = dense_to_edges(run_dense_fixpoint(to_dense(edges, n), k))
        want = naive_ktruss(edges, n, k)
        assert got == want

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(min_value=0, max_value=10_000),
        k=st.sampled_from([3, 4]),
        density=st.floats(min_value=0.05, max_value=0.3),
    )
    def test_matches_naive_on_random_graphs(self, seed, k, density):
        n = 64
        rng = np.random.RandomState(seed)
        upper = np.triu((rng.rand(n, n) < density), k=1)
        edges = [(int(u), int(v)) for u, v in zip(*np.nonzero(upper))]
        got = dense_to_edges(run_dense_fixpoint(to_dense(edges, n), k))
        want = naive_ktruss(edges, n, k)
        assert got == want

    def test_step_preserves_symmetry(self):
        rng = np.random.RandomState(7)
        upper = np.triu((rng.rand(128, 128) < 0.1), k=1).astype(np.float32)
        a = upper + upper.T
        a_next, _ = model.ktruss_step(jnp.asarray(a), jnp.float32(1.0), tile=64)
        a_next = np.asarray(a_next)
        np.testing.assert_array_equal(a_next, a_next.T)

    def test_removed_counts_directed_entries(self):
        a = to_dense([(0, 1), (0, 2), (1, 2), (2, 3)], 64)
        _, removed = model.ktruss_step(jnp.asarray(a), jnp.float32(1.0), tile=64)
        assert float(removed) == 2.0

    def test_support_sum_is_six_times_triangles(self):
        # two triangles sharing an edge: {0,1,2} and {1,2,3}
        a = to_dense([(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)], 64)
        assert float(model.support_sum(jnp.asarray(a), tile=64)) == 12.0
