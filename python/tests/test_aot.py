"""AOT path smoke tests: the lowered HLO text must exist-after-lowering,
parse as HLO, and — crucially — execute on the CPU PJRT client with the
same numbers as the jax-level model. This is the python half of the
interchange contract with ``rust/src/runtime``."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import aot, model


@pytest.fixture(scope="module")
def support_hlo_64():
    spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    fn = lambda a: (model.support(a, tile=64),)
    return aot.to_hlo_text(jax.jit(fn).lower(spec))


@pytest.fixture(scope="module")
def step_hlo_64():
    a_spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    t_spec = jax.ShapeDtypeStruct((), jnp.float32)
    fn = lambda a, t: model.ktruss_step(a, t, tile=64)
    return aot.to_hlo_text(jax.jit(fn).lower(a_spec, t_spec))


def test_hlo_text_mentions_entry(support_hlo_64):
    assert "ENTRY" in support_hlo_64
    assert "f32[64,64]" in support_hlo_64


def test_hlo_has_no_custom_calls(support_hlo_64, step_hlo_64):
    # interpret=True pallas must lower to plain HLO ops; a custom-call
    # would be unloadable by the CPU PJRT client in rust
    for text in (support_hlo_64, step_hlo_64):
        assert "custom-call" not in text, "Mosaic custom-call leaked into HLO"


def _run_hlo(hlo_text, args):
    """Compile HLO text on the CPU PJRT client and run it — mirrors what
    rust/src/runtime does via the xla crate."""
    client = xc.make_cpu_client()
    comp = xc.XlaComputation(
        xc._xla.hlo_module_proto_from_text(hlo_text).SerializeToString()
    )
    exe = client.compile(comp.as_serialized_hlo_module_proto())
    bufs = [client.buffer_from_pyval(a) for a in args]
    out = exe.execute(bufs)
    return [np.asarray(b) for b in out]


def test_support_hlo_executes_like_jax(support_hlo_64):
    rng = np.random.RandomState(3)
    upper = np.triu((rng.rand(64, 64) < 0.15), k=1).astype(np.float32)
    a = upper + upper.T
    try:
        (got,) = _run_hlo(support_hlo_64, [a])
    except Exception as e:  # pragma: no cover - depends on xla_client API surface
        pytest.skip(f"local PJRT text-execution unavailable: {e}")
    want = np.asarray(model.support(jnp.asarray(a), tile=64))
    np.testing.assert_array_equal(got.reshape(64, 64), want)


def test_step_hlo_executes_like_jax(step_hlo_64):
    rng = np.random.RandomState(4)
    upper = np.triu((rng.rand(64, 64) < 0.15), k=1).astype(np.float32)
    a = upper + upper.T
    try:
        out = _run_hlo(step_hlo_64, [a, np.float32(1.0)])
    except Exception as e:  # pragma: no cover
        pytest.skip(f"local PJRT text-execution unavailable: {e}")
    want_a, want_removed = model.ktruss_step(jnp.asarray(a), jnp.float32(1.0), tile=64)
    np.testing.assert_array_equal(out[0].reshape(64, 64), np.asarray(want_a))
    assert float(out[1]) == float(want_removed)
