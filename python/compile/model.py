"""L2: the dense linear-algebraic K-truss compute graph (Algorithm 1),
built on the L1 Pallas support kernel.

Exported functions (AOT-lowered to HLO text by ``aot.py``):

* ``support(A)``            — ``S = (AᵀA) ∘ A`` via the Pallas kernel.
* ``ktruss_step(A, thr)``   — one support+prune iteration, returning the
  pruned adjacency and the number of removed entries.

The convergence loop deliberately lives in the **rust coordinator**
(L3): the step function is side-effect free and shape-stable, so rust
re-invokes the compiled executable until ``removed == 0``. Python never
runs at request time.
"""

import jax.numpy as jnp

from compile.kernels.eager_support import support_pallas


def support(a, tile=128):
    """Edge-support matrix of a symmetric 0/1 adjacency."""
    return support_pallas(a, tile=tile)


def ktruss_step(a, threshold, tile=128):
    """One Algorithm-1 iteration on a symmetric dense adjacency.

    Args:
        a: (n, n) f32 symmetric 0/1 matrix, n % tile == 0 (zero-padded
           by the rust caller).
        threshold: f32 scalar, ``k - 2``.

    Returns:
        (a_next, removed): pruned adjacency; removed counts *directed*
        entries (2x undirected edges), as an f32 scalar.
    """
    s = support(a, tile=tile)
    m = (s >= threshold).astype(a.dtype)
    a_next = a * m
    removed = jnp.sum(a) - jnp.sum(a_next)
    return a_next, removed


def support_sum(a, tile=128):
    """Total support mass = 6x triangle count (each triangle contributes
    1 to six directed entries). Exported for cheap rust-side validation
    of the dense path against the sparse path's triangle count."""
    return jnp.sum(support(a, tile=tile))
