"""AOT lowering: jax (L2+L1) → HLO *text* → ``artifacts/``.

HLO text — not ``lowered.compile().serialize()`` and not a serialized
``HloModuleProto`` — is the interchange format: jax ≥ 0.5 emits protos
with 64-bit instruction ids that the rust crate's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (one per exported entry point × block size):

    support_{n}.hlo.txt       S = (AᵀA) ∘ A            : f32[n,n] -> (f32[n,n],)
    ktruss_step_{n}.hlo.txt   one Alg-1 iteration       : f32[n,n], f32[] -> (f32[n,n], f32[])

Run ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``make artifacts`` target). Python never runs after this point.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Block sizes exported by default. 256 is the production default
# (2 MiB per f32 operand); 128 exists for small-graph latency and tests.
SIZES = (128, 256)
TILE = 128


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_support(n: int) -> str:
    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    fn = lambda a: (model.support(a, tile=TILE),)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_ktruss_step(n: int) -> str:
    a_spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    t_spec = jax.ShapeDtypeStruct((), jnp.float32)
    fn = lambda a, t: model.ktruss_step(a, t, tile=TILE)
    return to_hlo_text(jax.jit(fn).lower(a_spec, t_spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", type=int, nargs="*", default=list(SIZES))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"tile": TILE, "entries": []}
    for n in args.sizes:
        assert n % TILE == 0, f"size {n} must be a multiple of tile {TILE}"
        for name, text in (
            (f"support_{n}", lower_support(n)),
            (f"ktruss_step_{n}", lower_ktruss_step(n)),
        ):
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest["entries"].append(
                {"name": name, "file": f"{name}.hlo.txt", "n": n, "chars": len(text)}
            )
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
