"""L1: Pallas tile kernel for the dense support computation
``S = (Aᵀ A) ∘ A``.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's GPU
kernel is SIMT — one CUDA thread per task, global-memory atomics. A TPU
has neither per-lane atomics nor a thread-per-task model, so we port the
paper's *insight* (uniform-cost fine-grained tasks) instead of its
mechanics: the adjacency matrix is tiled into ``T×T`` VMEM blocks and
each grid step runs one MXU contraction ``A[k,i]ᵀ @ A[k,j]`` — every
task (tile-triple) costs exactly the same, the perfectly load-balanced
limit of the paper's fine-grained decomposition. The BlockSpec grid
expresses the HBM↔VMEM schedule that CUDA expressed with threadblocks.

The kernel is lowered with ``interpret=True`` so the AOT HLO runs on the
CPU PJRT plugin (real-TPU lowering emits a Mosaic custom-call the CPU
client cannot execute); MXU/VMEM behaviour is *estimated* in
EXPERIMENTS.md §Perf from the block shapes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile edge: 128 matches the MXU systolic array (128x128) and
# keeps three f32 tiles (two inputs + accumulator) at 192 KiB, far under
# the ~16 MiB VMEM budget — leaving room for double-buffering.
DEFAULT_TILE = 128


def _support_kernel(a_ki_ref, a_kj_ref, mask_ref, o_ref):
    """One grid step: accumulate A[k,i]ᵀ @ A[k,j]; mask on the last k.

    Grid is (i_tiles, j_tiles, k_tiles) with k innermost so the output
    tile stays resident in VMEM across the contraction.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU contraction: (T, T)ᵀ @ (T, T) -> (T, T)
    o_ref[...] += jnp.dot(
        a_ki_ref[...].T, a_kj_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _mask():
        # the Hadamard ∘A: zero S where there is no edge
        o_ref[...] *= mask_ref[...]


@functools.partial(jax.jit, static_argnames=("tile",))
def support_pallas(a, tile=DEFAULT_TILE):
    """``S = (Aᵀ A) ∘ A`` for a symmetric (n, n) 0/1 matrix, n % tile == 0."""
    n = a.shape[0]
    assert a.shape == (n, n), a.shape
    assert n % tile == 0, (n, tile)
    grid = (n // tile, n // tile, n // tile)
    return pl.pallas_call(
        _support_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j, k: (k, i)),  # A[k, i]
            pl.BlockSpec((tile, tile), lambda i, j, k: (k, j)),  # A[k, j]
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),  # mask A[i, j]
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(a, a, a)


def _support_kernel_select(a_ki_ref, a_kj_ref, mask_ref, o_ref):
    """Masking-strategy variant (DESIGN.md §8 ablation): apply the ∘A
    Hadamard via ``jnp.where`` on the final k step instead of a
    multiply. Same math on 0/1 masks; exists to compare lowered HLO
    (select vs mul fuses differently on some backends)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ki_ref[...].T, a_kj_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _mask():
        o_ref[...] = jnp.where(mask_ref[...] != 0, o_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("tile",))
def support_pallas_select(a, tile=DEFAULT_TILE):
    """``S = (Aᵀ A) ∘ A`` with select-style masking (ablation twin of
    :func:`support_pallas`)."""
    n = a.shape[0]
    assert a.shape == (n, n), a.shape
    assert n % tile == 0, (n, tile)
    grid = (n // tile, n // tile, n // tile)
    return pl.pallas_call(
        _support_kernel_select,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j, k: (k, i)),
            pl.BlockSpec((tile, tile), lambda i, j, k: (k, j)),
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(a, a, a)


def vmem_bytes(tile=DEFAULT_TILE, dtype_bytes=4):
    """Resident VMEM footprint of one grid step (for §Perf estimates):
    two input tiles + mask tile + accumulator tile."""
    return 4 * tile * tile * dtype_bytes


def mxu_utilization_estimate(tile=DEFAULT_TILE):
    """Fraction of MXU issue slots doing useful work for one step: a
    T×T×T contraction on the 128×128 array is perfectly shaped when
    T % 128 == 0, degrading as T shrinks."""
    mxu = 128
    eff_rows = min(tile, mxu) / mxu
    eff_cols = min(tile, mxu) / mxu
    return eff_rows * eff_cols
