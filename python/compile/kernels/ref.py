"""Pure-jnp reference oracle for the dense linear-algebraic K-truss path.

These are the textbook forms of the paper's Algorithm 1 on a *symmetric*
dense adjacency matrix:

    S = (Aᵀ A) ∘ A          -- support: common-neighbor counts per edge
    M = S ≥ (k - 2);  A ← A ∘ M   -- prune

The Pallas kernel in ``eager_support.py`` must match ``support_ref``
bit-for-bit on 0/1 inputs (integer-valued f32 arithmetic is exact well
past any block size we use).
"""

import jax.numpy as jnp


def support_ref(a):
    """Edge supports of a symmetric 0/1 adjacency matrix.

    ``S[i, j]`` = number of triangles through edge (i, j); zero where
    there is no edge.
    """
    return (a.T @ a) * a


def ktruss_step_ref(a, threshold):
    """One support+prune iteration of Algorithm 1.

    Args:
        a: symmetric 0/1 adjacency (f32).
        threshold: scalar ``k - 2`` (f32).

    Returns:
        (a_next, removed): pruned adjacency and the number of directed
        entries removed (2x the undirected edge count).
    """
    s = support_ref(a)
    m = (s >= threshold).astype(a.dtype)
    a_next = a * m
    removed = jnp.sum(a) - jnp.sum(a_next)
    return a_next, removed


def ktruss_fixpoint_ref(a, threshold, max_iters=64):
    """Iterate ``ktruss_step_ref`` to convergence (python loop; oracle
    only — the production loop lives in the rust coordinator)."""
    for _ in range(max_iters):
        a_next, removed = ktruss_step_ref(a, threshold)
        a = a_next
        if float(removed) == 0.0:
            break
    return a
