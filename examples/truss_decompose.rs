//! Truss decomposition as community analysis: build a graph with
//! planted dense communities, decompose it, and show how trussness
//! separates community cores from the random background — the
//! application the paper's introduction motivates (K-trusses as
//! "highly connected subgraphs").
//!
//! Run: `cargo run --release --example truss_decompose`

use ktruss::algo::decompose::decompose;
use ktruss::graph::builder;
use ktruss::graph::coo::EdgeList;
use ktruss::util::Rng;

fn main() {
    // plant three cliques of sizes 8, 12, 16 in a sparse random sea
    let n = 2_000;
    let mut rng = Rng::new(5);
    let mut el = EdgeList::new(n);
    let mut planted = Vec::new();
    let mut next = 0u32;
    for size in [8u32, 12, 16] {
        for u in next..next + size {
            for v in (u + 1)..next + size {
                el.push(u, v);
            }
        }
        planted.push((next, next + size));
        next += size;
    }
    // background noise: 3000 random edges
    for _ in 0..3_000 {
        let u = rng.below(n as u64) as u32;
        let v = rng.below(n as u64) as u32;
        el.push(u, v);
    }
    let g = builder::from_edge_list(el);
    println!("graph: {}", ktruss::graph::stats::stats(&g));

    let d = decompose(&g);
    println!("kmax = {} (planted max clique K16 ⇒ expected 16)", d.kmax);
    assert_eq!(d.kmax, 16, "the K16 clique must dominate");

    println!("\ntrussness histogram:");
    for (k, count) in d.histogram() {
        let bar = "#".repeat((count as f64).log2().max(0.0) as usize + 1);
        println!("  k={k:>3}: {count:>6} {bar}");
    }

    // the k-truss at each planted level recovers exactly the clique
    // cores: the k-truss is every edge with trussness ≥ k, i.e. the
    // union of the planted cliques of size ≥ k
    for (k, min_clique_idx) in [(16u32, 2usize), (12, 1)] {
        let edges = d.truss_edges(k);
        let in_cores = edges.iter().all(|&(u, v)| {
            planted[min_clique_idx..]
                .iter()
                .any(|&(lo, hi)| (lo..hi).contains(&u) && (lo..hi).contains(&v))
        });
        println!(
            "\n{k}-truss: {} edges, all inside planted cliques of size ≥ {k}? {in_cores}",
            edges.len()
        );
        assert!(in_cores, "k={k} truss must be the planted clique cores");
    }
    println!("\ncommunity cores recovered exactly by trussness. ✓");
}
