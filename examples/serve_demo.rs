//! Serving demo: the sharded executor as a long-lived service — a
//! mixed-priority stream of K-truss / K_max / triangle jobs over graphs
//! of varying size, with soft deadlines on the interactive class,
//! cost-model batch packing across shards, and per-shard metrics.
//!
//! Run: `cargo run --release --example serve_demo`

use ktruss::algo::support::Mode;
use ktruss::coordinator::{JobKind, JobOutput};
use ktruss::serve::{Executor, Priority, ServeConfig, SubmitOpts};
use ktruss::util::{Rng, Timer};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let ex = Executor::start(ServeConfig {
        shards: 2,
        workers_per_shard: 2,
        max_batch: 8,
        ..Default::default()
    });
    let mut rng = Rng::new(2024);
    let total_jobs = 48;
    println!("submitting {total_jobs} mixed-priority jobs (sizes 60..2000 vertices)…");

    let t = Timer::start();
    let mut tickets = Vec::new();
    for i in 0..total_jobs {
        // alternate small (dense-routable) and large (sparse) graphs
        let n = if i % 3 == 0 { rng.range(60, 220) } else { rng.range(500, 2000) };
        let m = (2 * n + rng.range(0, 3 * n)).min(n * (n - 1) / 2);
        let g = Arc::new(ktruss::gen::rmat::rmat(
            n,
            m,
            ktruss::gen::rmat::RmatParams::social(),
            &mut rng,
        ));
        let kind = match i % 4 {
            0 => JobKind::Ktruss { k: 3, mode: Mode::Fine },
            1 => JobKind::Ktruss { k: 4, mode: Mode::Coarse },
            2 => JobKind::Triangles,
            _ => JobKind::Kmax,
        };
        // small graphs are the interactive class: high priority, soft
        // deadline; the rest is best-effort batch work
        let opts = if i % 3 == 0 {
            SubmitOpts {
                priority: Priority::High,
                deadline: Some(Duration::from_millis(250)),
            }
        } else {
            SubmitOpts { priority: Priority::Low, deadline: None }
        };
        tickets.push((i, ex.submit_with(g, kind, opts)));
    }

    let mut dense = 0usize;
    let mut sparse = 0usize;
    for (i, ticket) in tickets {
        let r = ticket.wait();
        match r.engine {
            ktruss::coordinator::Engine::DenseXla => dense += 1,
            ktruss::coordinator::Engine::SparseCpu => sparse += 1,
        }
        let summary = match r.output.expect("job must succeed") {
            JobOutput::Ktruss { truss_edges, iterations, .. } => {
                format!("ktruss: {truss_edges} edges, {iterations} iters")
            }
            JobOutput::Kmax { kmax, truss_edges } => format!("kmax={kmax} ({truss_edges} edges)"),
            JobOutput::Decompose { kmax, .. } => format!("decompose kmax={kmax}"),
            JobOutput::Triangles { count } => format!("{count} triangles"),
        };
        if i < 6 {
            println!("  job {i:2} [{}] {:7.2} ms  {summary}", r.engine, r.wall_ms);
        }
    }
    println!("  …");
    println!(
        "all {total_jobs} jobs done in {:.1} ms  (routing: {dense} dense-xla, {sparse} sparse-cpu)",
        t.elapsed_ms()
    );
    println!("metrics: {}", ex.metrics.render());
    println!("{}", ex.metrics.render_shards());
    if let (Some(p50), Some(p99)) = (ex.metrics.quantile(0.50), ex.metrics.quantile(0.99)) {
        println!("serving latency: p50 {p50:.3} ms  p99 {p99:.3} ms");
    }
    println!(
        "cost model after the run: {:.2} ns/step over {} jobs",
        ex.cost_model.ns_per_step(),
        ex.cost_model.samples()
    );
    ex.shutdown();
}
