//! The paper's headline experiment on one graph: simulate all four
//! device/granularity combinations and print the speedup breakdown,
//! including *why* the GPU coarse kernel collapses (the per-term
//! decomposition of the kernel estimate).
//!
//! Run: `cargo run --release --example gpu_vs_cpu [-- graph-name]`

use ktruss::algo::support::Mode;
use ktruss::cost::trace::trace_supports;
use ktruss::graph::ZCsr;
use ktruss::sim::{gpu, machine::GpuMachine, simulate_ktruss, table1_configs};

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "as20000102".to_string());
    let spec = ktruss::gen::suite::by_name(&name).expect("unknown suite graph");
    let g = ktruss::gen::suite::load(spec, 0.25).expect("generate");
    println!("# {} replica: {}", name, ktruss::graph::stats::stats(&g));

    let res = simulate_ktruss(&g, 3, &table1_configs());
    println!("\nsimulated K=3 totals:");
    for r in &res {
        println!(
            "  {:10} {:10.3} ms   {:10.3} ME/s   ({} iterations)",
            r.label,
            r.time_ms(),
            r.me_per_s,
            r.iterations
        );
    }
    let t = |l: &str| res.iter().find(|r| r.label.contains(l)).unwrap().seconds;
    println!("\nspeedups (fine over coarse):");
    println!("  CPU 48T: {:.2}x", t("CPU-C") / t("CPU-F"));
    println!("  GPU:     {:.2}x", t("GPU-C") / t("GPU-F"));
    println!("  (paper, full-size: CPU 1.26-1.48x, GPU 9.97-16.93x)");

    // decompose the first support kernel to show where GPU-coarse dies
    let z = ZCsr::from_csr(&g);
    let mut s = Vec::new();
    let tr = trace_supports(&z, &mut s);
    let m = GpuMachine::v100();
    println!("\nfirst support kernel, GPU model term breakdown:");
    for mode in [Mode::Coarse, Mode::Fine] {
        let est = gpu::support_kernel(&m, &tr, z.row_ptr(), mode);
        println!(
            "  {mode:6}: throughput {:9.1} us | serial-tail {:9.1} us | bandwidth {:7.1} us  -> total {:9.1} us",
            est.throughput_s * 1e6,
            est.tail_s * 1e6,
            est.bandwidth_s * 1e6,
            est.total_s() * 1e6
        );
    }
    println!("(coarse is tail-dominated: one mega-row serializes a lone warp — paper §III-A)");
}
