//! Run the K-truss over (a subset of) the Table-I replica suite and
//! print per-graph results with kmax — the paper's workload end-to-end
//! on the sparse engine.
//!
//! Run: `cargo run --release --example snap_suite [-- scale]`
//! (default scale 0.1; full-size graphs take minutes on one core)

use ktruss::algo::kmax::kmax;
use ktruss::algo::ktruss::ktruss;
use ktruss::algo::support::Mode;
use ktruss::util::fmt::{count_k, Table};
use ktruss::util::Timer;

fn main() {
    let scale: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.1);
    let names = [
        "ca-GrQc",
        "p2p-Gnutella08",
        "as20000102",
        "oregon1_010331",
        "oregon2_010331",
        "ca-AstroPh",
        "email-Enron",
        "soc-Epinions1",
        "roadNet-PA",
    ];
    println!("# snap_suite at scale {scale}");
    let mut t = Table::new(vec![
        "graph", "V", "E", "3-truss edges", "iters", "kmax", "ms(k3)", "ms(kmax)",
    ]);
    for name in names {
        let spec = ktruss::gen::suite::by_name(name).expect("suite name");
        let g = ktruss::gen::suite::load(spec, scale).expect("generate");
        let timer = Timer::start();
        let k3 = ktruss(&g, 3, Mode::Fine);
        let ms_k3 = timer.elapsed_ms();
        let timer = Timer::start();
        let km = kmax(&g);
        let ms_km = timer.elapsed_ms();
        t.row(vec![
            name.to_string(),
            count_k(g.n()),
            count_k(g.nnz()),
            k3.truss.nnz().to_string(),
            k3.iterations.to_string(),
            km.kmax.to_string(),
            format!("{ms_k3:.1}"),
            format!("{ms_km:.1}"),
        ]);
        eprintln!("  [{name} done]");
    }
    println!("{}", t.render());
}
