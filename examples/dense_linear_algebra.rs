//! The dense linear-algebraic path end-to-end: a graph flows through
//! the AOT-compiled jax+Pallas artifacts (HLO via PJRT) and the result
//! is cross-checked against the sparse rust path — the three-layer
//! composition in one binary.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example dense_linear_algebra`

use ktruss::algo::ktruss::ktruss;
use ktruss::algo::support::Mode;
use ktruss::algo::triangle;
use ktruss::runtime::DenseEngine;
use ktruss::util::{Rng, Timer};

fn main() -> anyhow::Result<()> {
    let engine = DenseEngine::new()?;
    println!(
        "dense engine up (max block n={}), PJRT platform: {}",
        engine.max_n(),
        ktruss::runtime::Runtime::global()?.platform()
    );

    let g = ktruss::gen::community::communities(200, 1500, 20, &mut Rng::new(99));
    println!("graph: {}", ktruss::graph::stats::stats(&g));

    // supports through the MXU-tiled Pallas kernel (S = AᵀA ∘ A)
    let t = Timer::start();
    let dense_sup = engine.supports(&g)?;
    println!(
        "dense supports: {} edges in {:.2} ms (first call includes XLA compile)",
        dense_sup.len(),
        t.elapsed_ms()
    );
    let naive = triangle::edge_supports_naive(&g);
    assert_eq!(dense_sup, naive, "dense supports must match the naive oracle");
    println!("  ✓ matches naive per-edge supports");

    // full K-truss: rust drives the convergence loop over the AOT step
    for k in [3u32, 4, 6, 8] {
        let t = Timer::start();
        let (dense_truss, iters) = engine.ktruss(&g, k)?;
        let dense_ms = t.elapsed_ms();
        let t = Timer::start();
        let sparse = ktruss(&g, k, Mode::Fine);
        let sparse_ms = t.elapsed_ms();
        assert_eq!(dense_truss, sparse.truss, "k={k}");
        println!(
            "  k={k}: {} edges, dense {iters} iters / {dense_ms:.2} ms, sparse {} iters / {sparse_ms:.2} ms  ✓ identical truss",
            dense_truss.nnz(),
            sparse.iterations,
        );
    }
    println!("dense path verified against sparse path across k.");
    Ok(())
}
