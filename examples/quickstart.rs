//! Quickstart: generate a small graph, compute its 3-truss with both
//! parallel granularities, check they agree, and peek at the task-cost
//! distributions that motivate the paper.
//!
//! Run: `cargo run --release --example quickstart`

use ktruss::algo::ktruss::ktruss;
use ktruss::algo::support::Mode;
use ktruss::cost::trace::trace_supports;
use ktruss::graph::ZCsr;
use ktruss::util::Rng;

fn main() {
    // a hub-heavy graph: the imbalanced case the paper targets
    let g = ktruss::gen::rmat::rmat(
        5_000,
        30_000,
        ktruss::gen::rmat::RmatParams::autonomous_system(),
        &mut Rng::new(7),
    );
    println!("graph: {}", ktruss::graph::stats::stats(&g));

    // the two granularities compute the same truss
    let coarse = ktruss(&g, 3, Mode::Coarse);
    let fine = ktruss(&g, 3, Mode::Fine);
    assert_eq!(coarse.truss, fine.truss);
    println!(
        "3-truss: {} of {} edges survive in {} iterations",
        fine.truss.nnz(),
        g.nnz(),
        fine.iterations
    );

    // why fine-grained wins: coarse task costs are wildly skewed
    let z = ZCsr::from_csr(&g);
    let mut s = Vec::new();
    let tr = trace_supports(&z, &mut s);
    let coarse_dist = tr.coarse_summary(z.row_ptr()).unwrap();
    let fine_dist = tr.fine_summary().unwrap();
    println!(
        "coarse tasks (rows):     n={:7}  mean={:8.1}  max={:8.0}  imbalance={:6.1}x",
        coarse_dist.n,
        coarse_dist.mean,
        coarse_dist.max,
        coarse_dist.imbalance()
    );
    println!(
        "fine tasks (nonzeros):   n={:7}  mean={:8.1}  max={:8.0}  imbalance={:6.1}x",
        fine_dist.n,
        fine_dist.mean,
        fine_dist.max,
        fine_dist.imbalance()
    );
    println!("(imbalance = max/mean task cost; the paper's Fig 1 in numbers)");
}
